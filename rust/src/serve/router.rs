//! Load-adaptive request routing across data-parallel replicas.
//!
//! Training rebalances by *resizing* per-device batch shares; serving
//! cannot resize a request, so the same signal steers *where whole
//! micro-batches go*. The [`Router`] reuses the guarded
//! [`AdaptiveController`] unchanged: observed per-sample service times
//! feed `record`, and the controller's allocation over a nominal
//! 100-sample batch is reinterpreted as a **traffic-share table**
//! (percent of batches each replica should receive). All the training
//! guards carry over for free — EMA smoothing, cooldown, hysteresis,
//! and the freshness rule that refuses to rescore on stale data.
//!
//! Dispatch picks the replica maximizing `share / (1 + outstanding)` —
//! proportional steering with a least-outstanding correction, so a
//! replica that stops completing work stops attracting new work even
//! between rebalances.
//!
//! Two serving-specific rules:
//!
//! * **probe guarantee** — the freshness guard needs an observation
//!   from *every* replica, but a replica the router has steered away
//!   from produces none; left alone this deadlocks adaptation (one
//!   starved replica blocks every future rebalance). Any replica not
//!   routed to within `world * adapt_every` batches gets the next
//!   batch as a probe.
//! * **staleness of in-flight work** — routing is consulted only at
//!   dispatch. A batch in flight is never re-routed by a rebalance;
//!   re-convergence happens purely through where *new* batches go.

use crate::sched::{AdaptiveController, ControllerConfig, RebalanceEvent};
use crate::Result;

/// Nominal batch the controller's allocation is computed over; shares
/// are therefore percentages of offered traffic.
const ROUTE_SHARE_TOTAL: usize = 100;

/// How new micro-batches are spread across replicas.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutePolicy {
    /// Static round-robin (the baseline the bench gates against).
    RoundRobin,
    /// Guarded adaptive steering via [`AdaptiveController`].
    Adaptive,
}

impl RoutePolicy {
    pub fn parse(text: &str) -> Result<RoutePolicy> {
        match text.trim() {
            "rr" | "round-robin" | "static" => Ok(RoutePolicy::RoundRobin),
            "adaptive" => Ok(RoutePolicy::Adaptive),
            other => anyhow::bail!("unknown route policy {other:?} (round-robin|adaptive)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            RoutePolicy::RoundRobin => "round-robin",
            RoutePolicy::Adaptive => "adaptive",
        }
    }
}

/// Per-batch replica chooser; see the module docs for the policy.
#[derive(Debug)]
pub struct Router {
    policy: RoutePolicy,
    world: usize,
    controller: Option<AdaptiveController>,
    adapt_every: usize,
    /// Batches dispatched but not yet completed, per replica.
    outstanding: Vec<usize>,
    /// Batch index at which each replica was last dispatched to.
    last_routed: Vec<u64>,
    /// Total batches dispatched per replica (report).
    dispatched: Vec<usize>,
    batches: u64,
    probe_every: u64,
}

impl Router {
    /// A router over `initial_scores.len()` replicas. `initial_scores`
    /// seed the traffic shares (offline benchmark scores, as in
    /// training); `adapt_every` is the rebalance cadence in batches.
    pub fn new(
        policy: RoutePolicy,
        initial_scores: &[f64],
        cfg: ControllerConfig,
        adapt_every: usize,
    ) -> Result<Router> {
        let world = initial_scores.len();
        anyhow::ensure!(world >= 1, "router needs at least one replica");
        anyhow::ensure!(
            world <= ROUTE_SHARE_TOTAL,
            "router supports at most {ROUTE_SHARE_TOTAL} replicas, got {world}"
        );
        let adapt_every = adapt_every.max(1);
        let controller = match policy {
            RoutePolicy::RoundRobin => None,
            RoutePolicy::Adaptive => Some(AdaptiveController::new(
                cfg,
                initial_scores,
                ROUTE_SHARE_TOTAL,
                ROUTE_SHARE_TOTAL,
            )?),
        };
        Ok(Router {
            policy,
            world,
            controller,
            adapt_every,
            outstanding: vec![0; world],
            last_routed: vec![0; world],
            dispatched: vec![0; world],
            batches: 0,
            probe_every: (world * adapt_every) as u64,
        })
    }

    pub fn policy(&self) -> RoutePolicy {
        self.policy
    }

    pub fn world(&self) -> usize {
        self.world
    }

    /// Current traffic shares (percent per replica); uniform for
    /// round-robin.
    pub fn shares(&self) -> Vec<usize> {
        match &self.controller {
            Some(c) => c.allocation().to_vec(),
            None => vec![ROUTE_SHARE_TOTAL / self.world; self.world],
        }
    }

    /// Batches dispatched to each replica so far.
    pub fn dispatched(&self) -> &[usize] {
        &self.dispatched
    }

    /// Pick the replica for the next micro-batch and record the
    /// dispatch. Never re-routes in-flight work: the choice is made
    /// once, here.
    pub fn route(&mut self) -> usize {
        let r = match (&self.policy, &self.controller) {
            (RoutePolicy::RoundRobin, _) | (_, None) => (self.batches % self.world as u64) as usize,
            (RoutePolicy::Adaptive, Some(ctl)) => {
                // Probe guarantee: never let a replica starve out of the
                // freshness window (see module docs).
                let starved = (0..self.world)
                    .filter(|&r| self.batches.saturating_sub(self.last_routed[r]) >= self.probe_every)
                    .min_by_key(|&r| self.last_routed[r]);
                starved.unwrap_or_else(|| {
                    let alloc = ctl.allocation();
                    let mut best = 0;
                    let mut best_w = f64::MIN;
                    for r in 0..self.world {
                        let w = alloc[r] as f64 / (1.0 + self.outstanding[r] as f64);
                        if w > best_w {
                            best_w = w;
                            best = r;
                        }
                    }
                    best
                })
            }
        };
        self.outstanding[r] += 1;
        self.last_routed[r] = self.batches;
        self.dispatched[r] += 1;
        self.batches += 1;
        r
    }

    /// Report a completed batch: replica `rank` served `step` (its
    /// dispatch sequence number) at `per_sample_s` observed seconds per
    /// request. Feeds the controller and, on the `adapt_every` cadence,
    /// lets a guarded rebalance land. Returns `true` when the traffic
    /// shares changed.
    pub fn on_complete(&mut self, rank: usize, step: usize, per_sample_s: f64) -> Result<bool> {
        assert!(rank < self.world, "rank {rank} out of range");
        self.outstanding[rank] = self.outstanding[rank].saturating_sub(1);
        let Some(ctl) = &mut self.controller else {
            return Ok(false);
        };
        ctl.record(rank, step, per_sample_s);
        if (step + 1) % self.adapt_every == 0 {
            return Ok(ctl.maybe_rebalance(step)?.is_some());
        }
        Ok(false)
    }

    /// Rebalance events applied so far (empty for round-robin).
    pub fn events(&self) -> &[RebalanceEvent] {
        self.controller.as_ref().map_or(&[], |c| c.events())
    }

    /// Drain the applied rebalance events (for the report).
    pub fn take_events(&mut self) -> Vec<RebalanceEvent> {
        self.controller
            .as_mut()
            .map_or_else(Vec::new, |c| c.take_events())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_parse_and_names() {
        assert_eq!(RoutePolicy::parse("rr").unwrap(), RoutePolicy::RoundRobin);
        assert_eq!(
            RoutePolicy::parse(" round-robin ").unwrap(),
            RoutePolicy::RoundRobin
        );
        assert_eq!(RoutePolicy::parse("adaptive").unwrap(), RoutePolicy::Adaptive);
        assert!(RoutePolicy::parse("random").is_err());
        assert_eq!(RoutePolicy::Adaptive.name(), "adaptive");
    }

    #[test]
    fn round_robin_cycles() {
        let mut r = Router::new(
            RoutePolicy::RoundRobin,
            &[1.0, 1.0, 1.0],
            ControllerConfig::default(),
            5,
        )
        .unwrap();
        let picks: Vec<usize> = (0..6).map(|_| r.route()).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
        assert!(!r.on_complete(0, 0, 1e-3).unwrap());
        assert!(r.events().is_empty());
    }

    #[test]
    fn adaptive_prefers_high_share_low_outstanding() {
        let mut r = Router::new(
            RoutePolicy::Adaptive,
            &[1.0, 1.0],
            ControllerConfig::default(),
            5,
        )
        .unwrap();
        // Equal shares: first pick is replica 0, and with it loaded the
        // next pick must move to replica 1.
        assert_eq!(r.route(), 0);
        assert_eq!(r.route(), 1);
        // Complete 1's batch only: 1 is now strictly less loaded.
        r.on_complete(1, 1, 1e-3).unwrap();
        assert_eq!(r.route(), 1);
    }

    #[test]
    fn adaptive_rebalances_toward_fast_replica() {
        let cfg = ControllerConfig {
            cooldown_steps: 4,
            freshness_steps: 50,
            shift_cap: 0,
            ..ControllerConfig::default()
        };
        let mut r = Router::new(RoutePolicy::Adaptive, &[1.0, 1.0], cfg, 2).unwrap();
        let before = r.shares();
        let mut changed = false;
        // Replica 0 reports 3x the service time of replica 1.
        for step in 0..40 {
            let _ = r.route();
            changed |= r.on_complete(step % 2, step, if step % 2 == 0 { 3e-3 } else { 1e-3 }).unwrap();
        }
        assert!(changed, "drift this large must land a rebalance");
        let after = r.shares();
        assert!(
            after[1] > before[1] && after[0] < before[0],
            "shares must shift toward the fast replica: {before:?} -> {after:?}"
        );
        assert!(!r.events().is_empty());
        assert!(!r.take_events().is_empty());
        assert!(r.events().is_empty(), "take_events drains");
    }

    #[test]
    fn probe_guarantee_revisits_starved_replica() {
        let cfg = ControllerConfig {
            shift_cap: 0,
            freshness_steps: 1000,
            ..ControllerConfig::default()
        };
        let mut r = Router::new(RoutePolicy::Adaptive, &[1.0, 0.02], cfg, 2).unwrap();
        // Replica 1's share collapses to min_share; without probing it
        // would rarely be routed to once replica 0 keeps completing.
        let mut saw_probe = false;
        for step in 0..30 {
            let pick = r.route();
            r.on_complete(pick, step, 1e-3).unwrap();
            if pick == 1 {
                saw_probe = true;
            }
        }
        assert!(saw_probe, "starved replica must still be probed");
        assert!(r.dispatched()[1] >= 2, "probed at least every world*adapt_every");
    }
}
