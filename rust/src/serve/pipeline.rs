//! Pipeline-parallel micro-batch execution over the CommTensor p2p
//! verbs.
//!
//! One [`StagePipeline`] is one data-parallel replica: each pipeline
//! stage owns a contiguous layer range of the [`StageModel`] and a
//! dedicated worker thread with its own rank on a private
//! [`InprocMesh`]; activations flow stage-to-stage as f32 CommTensor
//! payloads under `send_tagged` / `recv_tagged`.
//!
//! The tag discipline is `group/kaitian.rs`'s, generalized to
//! concurrent issue: the front-end reserves every inter-stage link tag
//! for a micro-batch *at submit time, in program order*, from the
//! lock-free [`PtpTagTable`], then fans a ticket out to every stage.
//! Stages execute in any interleaving — batch `k+1` can occupy stage 0
//! while batch `k` is still in stage 1 (that overlap is the pipeline's
//! whole point) — and the per-link FIFO match of the transport plus
//! the pre-reserved, per-link-monotonic tags keep every transfer
//! paired with its batch, exactly as the A/B/C chunk stages of the
//! kaitian group pipeline pair theirs.
//!
//! Heterogeneity: compute here is a synthetic dense model, identical
//! on every replica, so an optional *throttle* stretches each stage's
//! wall time to the device speed model (the same relative-throttle
//! trick the real-mode trainer uses). Bitwise parity with the
//! single-device forward is unaffected — the throttle only sleeps.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::collectives::chunk::PtpTagTable;
use crate::collectives::Communicator;
use crate::comm::tensor::{CommTensor, DType};
use crate::transport::InprocMesh;
use crate::Result;

use super::model::{StageModel, StagePlan};

/// Models a stage's wall time: `(stage, batch_len, seq) -> seconds`.
/// The stage sleeps out any remainder after real compute.
pub type StageThrottle = Arc<dyn Fn(usize, usize, u64) -> f64 + Send + Sync>;

/// A completed micro-batch, delivered on the pipeline's done channel.
#[derive(Debug)]
pub struct PipelineDone {
    /// Which replica finished it (as passed to [`StagePipeline::spawn`]).
    pub replica: usize,
    /// Submit sequence number within that replica.
    pub seq: u64,
    /// Samples in the batch.
    pub n: usize,
    /// Final activations, flat `n * width`.
    pub output: Vec<f32>,
}

/// One ticket per stage per micro-batch (issue-time fan-out).
struct Ticket {
    seq: u64,
    n: usize,
    /// Pre-reserved transport tag for each inter-stage link
    /// (`link_tags[s]` carries stage `s` -> `s+1`).
    link_tags: Arc<Vec<u64>>,
    /// The input activations; present only on the stage-0 ticket.
    input: Option<Vec<f32>>,
}

/// A running pipeline-parallel replica. Submit micro-batches with
/// [`StagePipeline::submit`]; completions arrive on the done channel
/// given at spawn, in per-replica submit order.
pub struct StagePipeline {
    stages: usize,
    width: usize,
    txs: Vec<mpsc::Sender<Ticket>>,
    tags: Arc<PtpTagTable>,
    next_seq: AtomicU64,
    busy_ns: Arc<Vec<AtomicU64>>,
    workers: Vec<JoinHandle<()>>,
}

impl StagePipeline {
    /// Spawn one worker thread per stage of `plan` over a private
    /// in-process mesh. `throttle`, when present, stretches stage wall
    /// times to a device speed model. Completions go to `done`.
    pub fn spawn(
        replica: usize,
        model: Arc<StageModel>,
        plan: &StagePlan,
        throttle: Option<StageThrottle>,
        done: mpsc::Sender<PipelineDone>,
    ) -> Result<StagePipeline> {
        let stages = plan.stages();
        anyhow::ensure!(stages >= 1, "pipeline needs at least one stage");
        anyhow::ensure!(
            plan.ranges.last().map(|r| r.1) == Some(model.layers())
                && plan.ranges.first().map(|r| r.0) == Some(0),
            "stage plan {:?} does not cover the model's {} layers",
            plan.ranges,
            model.layers()
        );
        let tags = Arc::new(PtpTagTable::new(stages));
        let busy_ns: Arc<Vec<AtomicU64>> =
            Arc::new((0..stages).map(|_| AtomicU64::new(0)).collect());
        let mut txs = Vec::with_capacity(stages);
        let mut workers = Vec::with_capacity(stages);
        let comms: Vec<Communicator> = InprocMesh::new(stages)
            .into_iter()
            .map(|e| Communicator::new(Arc::new(e)))
            .collect();
        for (stage, comm) in comms.into_iter().enumerate() {
            let (tx, rx) = mpsc::channel::<Ticket>();
            txs.push(tx);
            let (lo, hi) = plan.ranges[stage];
            let model = model.clone();
            let done = done.clone();
            let busy = busy_ns.clone();
            let throttle = throttle.clone();
            workers.push(std::thread::spawn(move || {
                stage_loop(
                    replica, stage, stages, lo, hi, &model, &comm, rx, &done, &busy, throttle,
                );
            }));
        }
        Ok(StagePipeline {
            stages,
            width: model.width(),
            txs,
            tags,
            next_seq: AtomicU64::new(0),
            busy_ns,
            workers,
        })
    }

    pub fn stages(&self) -> usize {
        self.stages
    }

    /// Submit one micro-batch of `n` samples (`input` flat
    /// `n * width`). Reserves all inter-stage link tags here, at issue
    /// time in program order (lock-free, any thread), then fans the
    /// ticket out; returns the replica-local sequence number.
    pub fn submit(&self, input: Vec<f32>, n: usize) -> Result<u64> {
        anyhow::ensure!(
            input.len() == n * self.width,
            "input length {} != n {} x width {}",
            input.len(),
            n,
            self.width
        );
        anyhow::ensure!(n >= 1, "empty micro-batch");
        let seq = self.next_seq.fetch_add(1, Ordering::Relaxed);
        let link_tags: Arc<Vec<u64>> = Arc::new(
            (0..self.stages.saturating_sub(1))
                .map(|s| self.tags.reserve(s, s + 1))
                .collect::<Result<_>>()?,
        );
        for (stage, tx) in self.txs.iter().enumerate() {
            let ticket = Ticket {
                seq,
                n,
                link_tags: link_tags.clone(),
                input: (stage == 0).then(|| input.clone()),
            };
            tx.send(ticket)
                .map_err(|_| anyhow::anyhow!("pipeline stage {stage} is gone"))?;
        }
        Ok(seq)
    }

    /// Accumulated per-stage compute seconds (throttled wall time).
    pub fn busy_s(&self) -> Vec<f64> {
        self.busy_ns
            .iter()
            .map(|ns| ns.load(Ordering::Relaxed) as f64 * 1e-9)
            .collect()
    }

    /// Close the ticket queues and join the stage workers. All
    /// submitted batches complete first: stage queues drain in order
    /// and every p2p transfer has a matching peer by construction.
    pub fn shutdown(mut self) {
        self.txs.clear();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for StagePipeline {
    fn drop(&mut self) {
        self.txs.clear();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn stage_loop(
    replica: usize,
    stage: usize,
    stages: usize,
    lo: usize,
    hi: usize,
    model: &StageModel,
    comm: &Communicator,
    rx: mpsc::Receiver<Ticket>,
    done: &mpsc::Sender<PipelineDone>,
    busy_ns: &[AtomicU64],
    throttle: Option<StageThrottle>,
) {
    let width = model.width();
    while let Ok(t) = rx.recv() {
        // Input: from the ticket (stage 0) or the upstream stage's
        // pre-reserved link tag.
        let act: Vec<f32> = match t.input {
            Some(x) => x,
            None => {
                let mut tensor = CommTensor::zeros(DType::F32, t.n * width);
                if comm
                    .recv_tagged(stage - 1, t.link_tags[stage - 1], DType::F32, tensor.as_bytes_mut())
                    .is_err()
                {
                    return; // peer gone mid-shutdown
                }
                tensor.to_f32()
            }
        };
        let t0 = Instant::now();
        let out = model.forward_layers(lo, hi, &act);
        if let Some(f) = &throttle {
            let target = f(stage, t.n, t.seq);
            let elapsed = t0.elapsed().as_secs_f64();
            if target > elapsed {
                std::thread::sleep(std::time::Duration::from_secs_f64(target - elapsed));
            }
        }
        busy_ns[stage].fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        if stage + 1 < stages {
            let tensor = CommTensor::from_f32(DType::F32, &out);
            if comm
                .send_tagged(stage + 1, t.link_tags[stage], DType::F32, tensor.as_bytes())
                .is_err()
            {
                return;
            }
        } else if done
            .send(PipelineDone {
                replica,
                seq: t.seq,
                n: t.n,
                output: out,
            })
            .is_err()
        {
            return; // front-end gone; nothing left to deliver to
        }
    }
}

/// Run `inputs` through a staged pipeline and return the outputs in
/// submit order — the blocking convenience the parity tests and bench
/// compare against `StageModel::forward`.
pub fn pipeline_forward(
    model: &StageModel,
    plan: &StagePlan,
    inputs: &[Vec<f32>],
) -> Result<Vec<Vec<f32>>> {
    let width = model.width();
    let (done_tx, done_rx) = mpsc::channel();
    let pipe = StagePipeline::spawn(0, Arc::new(model.clone()), plan, None, done_tx)?;
    for input in inputs {
        anyhow::ensure!(
            !input.is_empty() && input.len() % width == 0,
            "input length {} not a positive multiple of width {width}",
            input.len()
        );
        pipe.submit(input.clone(), input.len() / width)?;
    }
    let mut outputs: Vec<Option<Vec<f32>>> = vec![None; inputs.len()];
    for _ in 0..inputs.len() {
        let d = done_rx.recv()?;
        outputs[d.seq as usize] = Some(d.output);
    }
    pipe.shutdown();
    Ok(outputs.into_iter().map(|o| o.expect("one done per submit")).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pipeline_matches_single_device_bitwise() {
        let model = StageModel::new(6, 12, 42);
        let inputs: Vec<Vec<f32>> = (0..5).map(|i| model.input(3, i)).collect();
        let reference: Vec<Vec<f32>> = inputs.iter().map(|x| model.forward(x)).collect();
        for stages in [1, 2, 3] {
            let plan = StagePlan::balanced(&model.layer_costs(), &vec![1.0; stages]).unwrap();
            let outs = pipeline_forward(&model, &plan, &inputs).unwrap();
            for (o, r) in outs.iter().zip(&reference) {
                assert_eq!(o.len(), r.len());
                for (a, b) in o.iter().zip(r) {
                    assert_eq!(a.to_bits(), b.to_bits(), "{stages}-stage pipeline");
                }
            }
        }
    }

    #[test]
    fn in_flight_batches_overlap_stages() {
        // Submit many batches at once: the pipeline must accept them
        // all without waiting for completions (tickets queue per
        // stage), and completions arrive in submit order.
        let model = StageModel::new(4, 8, 7);
        let plan = StagePlan::balanced(&model.layer_costs(), &[1.0, 1.0]).unwrap();
        let (done_tx, done_rx) = mpsc::channel();
        let pipe = StagePipeline::spawn(3, Arc::new(model.clone()), &plan, None, done_tx).unwrap();
        for i in 0..16 {
            let seq = pipe.submit(model.input(2, i), 2).unwrap();
            assert_eq!(seq, i);
        }
        for i in 0..16 {
            let d = done_rx.recv().unwrap();
            assert_eq!((d.replica, d.seq, d.n), (3, i, 2));
        }
        assert!(pipe.busy_s().iter().all(|&b| b >= 0.0));
        pipe.shutdown();
    }

    #[test]
    fn throttle_stretches_stage_time() {
        let model = StageModel::new(2, 4, 1);
        let plan = StagePlan::balanced(&model.layer_costs(), &[1.0, 1.0]).unwrap();
        let (done_tx, done_rx) = mpsc::channel();
        let throttle: StageThrottle = Arc::new(|_, _, _| 5e-3);
        let pipe =
            StagePipeline::spawn(0, Arc::new(model.clone()), &plan, Some(throttle), done_tx)
                .unwrap();
        pipe.submit(model.input(1, 0), 1).unwrap();
        let d = done_rx.recv().unwrap();
        assert_eq!(d.n, 1);
        let busy = pipe.busy_s();
        assert!(
            busy.iter().all(|&b| b >= 4e-3),
            "each stage sleeps to the modeled time: {busy:?}"
        );
        pipe.shutdown();
    }

    #[test]
    fn submit_rejects_bad_shapes() {
        let model = StageModel::new(2, 4, 1);
        let plan = StagePlan::balanced(&model.layer_costs(), &[1.0]).unwrap();
        let (done_tx, _done_rx) = mpsc::channel();
        let pipe = StagePipeline::spawn(0, Arc::new(model), &plan, None, done_tx).unwrap();
        assert!(pipe.submit(vec![0.0; 3], 1).is_err(), "length mismatch");
        assert!(pipe.submit(vec![], 0).is_err(), "empty batch");
        pipe.shutdown();
    }

    #[test]
    fn spawn_rejects_mismatched_plan() {
        let model = StageModel::new(4, 4, 1);
        let short = StagePlan {
            ranges: vec![(0, 2)],
        };
        let (done_tx, _rx) = mpsc::channel();
        assert!(StagePipeline::spawn(0, Arc::new(model), &short, None, done_tx).is_err());
    }
}
