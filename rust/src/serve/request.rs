//! Synthetic open-loop request stream for the serving front-end.
//!
//! Serving benchmarks that draw arrivals from the *completion* process
//! (closed-loop) hide overload: a slow server slows its own offered
//! load. The stream here is **open-loop** — inter-arrival gaps are
//! drawn from an exponential distribution at a fixed offered rate,
//! independent of what the server does — so queueing delay under a
//! perturbed replica shows up in the latency tail instead of quietly
//! deflating throughput.
//!
//! Determinism: gaps come from [`Rng`] (xoshiro256++), so a `(rate,
//! slo, seed)` triple always replays the identical arrival sequence,
//! in the real-time front-end and the virtual-time simulator alike.

use crate::util::Rng;

/// One inference request: an id, when it arrived, and the absolute
/// deadline derived from the SLO at arrival time (all seconds on the
/// run's clock).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Request {
    pub id: u64,
    pub arrival_s: f64,
    pub deadline_s: f64,
}

impl Request {
    /// The SLO window this request was admitted under.
    pub fn slo_s(&self) -> f64 {
        self.deadline_s - self.arrival_s
    }
}

/// Deterministic Poisson (exponential-gap) arrival process at a fixed
/// offered rate. Iterator of [`Request`]s with monotonically increasing
/// arrival times; bound it with `.take(n)`.
#[derive(Debug, Clone)]
pub struct OpenLoopStream {
    rng: Rng,
    rate_rps: f64,
    slo_s: f64,
    next_id: u64,
    clock_s: f64,
}

impl OpenLoopStream {
    /// A stream offering `rate_rps` requests/second, each carrying a
    /// deadline `slo_s` seconds after its arrival.
    pub fn new(rate_rps: f64, slo_s: f64, seed: u64) -> Self {
        assert!(
            rate_rps.is_finite() && rate_rps > 0.0,
            "offered rate must be positive, got {rate_rps}"
        );
        assert!(
            slo_s.is_finite() && slo_s > 0.0,
            "SLO must be positive, got {slo_s}"
        );
        Self {
            rng: Rng::new(seed ^ 0x5e5e_0a11),
            rate_rps,
            slo_s,
            next_id: 0,
            clock_s: 0.0,
        }
    }
}

impl Iterator for OpenLoopStream {
    type Item = Request;

    fn next(&mut self) -> Option<Request> {
        // Inverse-CDF exponential gap; 1-u is in (0, 1] so ln is finite.
        let u = self.rng.next_f64();
        self.clock_s += -(1.0 - u).ln() / self.rate_rps;
        let r = Request {
            id: self.next_id,
            arrival_s: self.clock_s,
            deadline_s: self.clock_s + self.slo_s,
        };
        self.next_id += 1;
        Some(r)
    }
}

/// Nearest-rank percentile of an ascending-sorted slice (`q` in
/// `[0, 1]`); 0.0 for an empty slice.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let rank = (q.clamp(0.0, 1.0) * sorted.len() as f64).ceil() as usize;
    sorted[rank.clamp(1, sorted.len()) - 1]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stream_is_deterministic_and_monotonic() {
        let a: Vec<Request> = OpenLoopStream::new(1000.0, 0.05, 42).take(500).collect();
        let b: Vec<Request> = OpenLoopStream::new(1000.0, 0.05, 42).take(500).collect();
        assert_eq!(a, b, "same seed must replay the same arrivals");
        for w in a.windows(2) {
            assert!(w[1].arrival_s > w[0].arrival_s, "arrivals strictly increase");
            assert_eq!(w[1].id, w[0].id + 1);
        }
        for r in &a {
            assert!((r.slo_s() - 0.05).abs() < 1e-12);
        }
    }

    #[test]
    fn mean_gap_matches_offered_rate() {
        let n = 20_000;
        let last = OpenLoopStream::new(2000.0, 0.05, 7).nth(n - 1).unwrap();
        let mean_gap = last.arrival_s / n as f64;
        // Exponential mean 1/rate; 20k samples land within a few percent.
        assert!(
            (mean_gap - 5.0e-4).abs() / 5.0e-4 < 0.05,
            "mean gap {mean_gap}"
        );
    }

    #[test]
    fn different_seeds_differ() {
        let a = OpenLoopStream::new(1000.0, 0.05, 1).nth(10).unwrap();
        let b = OpenLoopStream::new(1000.0, 0.05, 2).nth(10).unwrap();
        assert_ne!(a.arrival_s, b.arrival_s);
    }

    #[test]
    fn percentile_nearest_rank() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 0.5), 2.0);
        assert_eq!(percentile(&xs, 0.75), 3.0);
        assert_eq!(percentile(&xs, 0.99), 4.0);
        assert_eq!(percentile(&xs, 1.0), 4.0);
        assert_eq!(percentile(&[], 0.5), 0.0);
        assert_eq!(percentile(&[7.5], 0.99), 7.5);
    }
}
