//! `artifacts/manifest.json` schema — the contract between `aot.py` (which
//! writes it) and the rust runtime (which loads executables through it).
//! Parsed with the in-repo JSON parser (`util::json`).

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

/// One tensor argument: shape + dtype, as recorded by aot.py.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    pub shape: Vec<i64>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn element_count(&self) -> usize {
        self.shape.iter().product::<i64>() as usize
    }

    fn from_json(v: &Json) -> Result<Self> {
        let shape = v
            .req("shape")?
            .as_arr()
            .ok_or_else(|| anyhow!("shape not an array"))?
            .iter()
            .map(|d| d.as_f64().map(|f| f as i64).ok_or_else(|| anyhow!("bad dim")))
            .collect::<Result<Vec<_>>>()?;
        Ok(Self {
            shape,
            dtype: v.str_req("dtype")?.to_string(),
        })
    }
}

/// A single lowered HLO file.
#[derive(Debug, Clone)]
pub struct FileEntry {
    pub file: String,
    pub bytes: u64,
    pub sha256: String,
}

impl FileEntry {
    fn from_json(v: &Json) -> Result<Self> {
        Ok(Self {
            file: v.str_req("file")?.to_string(),
            bytes: v.f64_req("bytes")? as u64,
            sha256: v.str_req("sha256")?.to_string(),
        })
    }
}

/// Per-program-family files: init/apply once, grad/eval per batch bucket.
#[derive(Debug, Clone)]
pub struct FileSet {
    pub init: FileEntry,
    pub apply: FileEntry,
    pub grad: BTreeMap<usize, FileEntry>,
    pub eval: BTreeMap<usize, FileEntry>,
}

fn bucket_map(v: &Json) -> Result<BTreeMap<usize, FileEntry>> {
    v.as_obj()
        .ok_or_else(|| anyhow!("bucket file map is not an object"))?
        .iter()
        .map(|(k, f)| {
            Ok((
                k.parse::<usize>().context("bucket key not an integer")?,
                FileEntry::from_json(f)?,
            ))
        })
        .collect()
}

/// Manifest entry for one model preset (e.g. "mobinet").
#[derive(Debug, Clone)]
pub struct ProgramManifest {
    pub param_count: usize,
    pub buckets: Vec<usize>,
    pub hyper_len: usize,
    pub hyper_layout: Vec<String>,
    /// bucket -> ordered batch input specs (x, y, mask).
    pub batch_inputs: BTreeMap<usize, Vec<TensorSpec>>,
    pub files: FileSet,
    /// Free-form model metadata (task, dims...) for diagnostics.
    pub meta: Json,
}

impl ProgramManifest {
    fn from_json(v: &Json) -> Result<Self> {
        let buckets = v
            .req("buckets")?
            .as_arr()
            .ok_or_else(|| anyhow!("buckets not an array"))?
            .iter()
            .map(|b| b.as_usize().ok_or_else(|| anyhow!("bad bucket")))
            .collect::<Result<Vec<_>>>()?;
        let files = v.req("files")?;
        let batch_inputs = v
            .req("batch_inputs")?
            .as_obj()
            .ok_or_else(|| anyhow!("batch_inputs not an object"))?
            .iter()
            .map(|(k, specs)| {
                let bucket = k.parse::<usize>().context("batch_inputs key")?;
                let specs = specs
                    .as_arr()
                    .ok_or_else(|| anyhow!("batch_inputs entry not an array"))?
                    .iter()
                    .map(TensorSpec::from_json)
                    .collect::<Result<Vec<_>>>()?;
                Ok((bucket, specs))
            })
            .collect::<Result<BTreeMap<_, _>>>()?;
        Ok(Self {
            param_count: v.usize_req("param_count")?,
            buckets,
            hyper_len: v.usize_req("hyper_len")?,
            hyper_layout: v
                .req("hyper_layout")?
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .filter_map(|s| s.as_str().map(String::from))
                .collect(),
            batch_inputs,
            files: FileSet {
                init: FileEntry::from_json(files.req("init")?)?,
                apply: FileEntry::from_json(files.req("apply")?)?,
                grad: bucket_map(files.req("grad")?)?,
                eval: bucket_map(files.req("eval")?)?,
            },
            meta: v.get("meta").cloned().unwrap_or(Json::Null),
        })
    }

    /// Smallest compiled bucket that can hold `n` samples.
    pub fn bucket_for(&self, n: usize) -> Result<usize> {
        self.buckets.iter().copied().find(|&b| b >= n).ok_or_else(|| {
            anyhow!(
                "no batch bucket >= {n} (largest lowered bucket is {:?})",
                self.buckets.last()
            )
        })
    }

    pub fn batch_specs(&self, bucket: usize) -> Result<&[TensorSpec]> {
        self.batch_inputs
            .get(&bucket)
            .map(|v| v.as_slice())
            .ok_or_else(|| anyhow!("bucket {bucket} not in manifest"))
    }
}

/// The whole manifest file.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub format: String,
    pub programs: BTreeMap<String, ProgramManifest>,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Self> {
        let v = Json::parse(text).context("parsing manifest.json")?;
        let format = v.str_req("format")?.to_string();
        if format != "hlo-text-v1" {
            bail!("unsupported artifact format {format:?}");
        }
        let programs = v
            .req("programs")?
            .as_obj()
            .ok_or_else(|| anyhow!("programs not an object"))?
            .iter()
            .map(|(k, p)| {
                Ok((
                    k.clone(),
                    ProgramManifest::from_json(p).with_context(|| format!("program {k:?}"))?,
                ))
            })
            .collect::<Result<BTreeMap<_, _>>>()?;
        Ok(Self { format, programs })
    }

    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        Self::parse(&text)
    }

    pub fn program(&self, name: &str) -> Result<&ProgramManifest> {
        self.programs.get(name).ok_or_else(|| {
            anyhow!(
                "program {name:?} not in manifest (have {:?}) — re-run `make artifacts`",
                self.programs.keys().collect::<Vec<_>>()
            )
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_manifest() -> Manifest {
        let json = r#"{
          "format": "hlo-text-v1",
          "programs": {
            "m": {
              "param_count": 10,
              "buckets": [4, 8, 16],
              "hyper_len": 4,
              "hyper_layout": ["lr", "momentum", "weight_decay", "grad_scale"],
              "meta": {"task": "image_classification"},
              "batch_inputs": {"4": [{"shape": [4, 2], "dtype": "float32"}]},
              "files": {
                "init": {"file": "m_init.hlo.txt", "bytes": 1, "sha256": "x"},
                "apply": {"file": "m_apply.hlo.txt", "bytes": 1, "sha256": "x"},
                "grad": {"4": {"file": "g4", "bytes": 1, "sha256": "x"}},
                "eval": {"4": {"file": "e4", "bytes": 1, "sha256": "x"}}
              },
              "outputs": {}
            }
          }
        }"#;
        Manifest::parse(json).unwrap()
    }

    #[test]
    fn bucket_selection_picks_smallest_fit() {
        let m = sample_manifest();
        let p = m.program("m").unwrap();
        assert_eq!(p.bucket_for(1).unwrap(), 4);
        assert_eq!(p.bucket_for(4).unwrap(), 4);
        assert_eq!(p.bucket_for(5).unwrap(), 8);
        assert_eq!(p.bucket_for(16).unwrap(), 16);
        assert!(p.bucket_for(17).is_err());
    }

    #[test]
    fn unknown_program_is_error() {
        let m = sample_manifest();
        assert!(m.program("nope").is_err());
    }

    #[test]
    fn specs_parse() {
        let m = sample_manifest();
        let p = m.program("m").unwrap();
        let specs = p.batch_specs(4).unwrap();
        assert_eq!(specs[0].shape, vec![4, 2]);
        assert_eq!(specs[0].dtype, "float32");
        assert_eq!(specs[0].element_count(), 8);
        assert_eq!(p.files.grad.get(&4).unwrap().file, "g4");
        assert_eq!(p.meta.str_req("task").unwrap(), "image_classification");
    }

    #[test]
    fn bad_format_rejected() {
        let json = r#"{"format": "hlo-text-v999", "programs": {}}"#;
        assert!(Manifest::parse(json).is_err());
    }
}
