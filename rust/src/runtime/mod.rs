//! PJRT runtime: load AOT artifacts (`artifacts/*.hlo.txt`) and execute
//! them from the coordinator's hot path.
//!
//! The flow (see `/opt/xla-example/load_hlo` and `python/compile/aot.py`):
//!
//! ```text
//! jax.jit(fn).lower(...) ──(HLO text)──▶ HloModuleProto::from_text_file
//!        (build time, python)             │
//!                                         ▼
//!                        XlaComputation::from_proto ─▶ client.compile
//!                                         │
//!                 execute(&[Literal]) ◀───┘  (request path, rust only)
//! ```
//!
//! HLO *text* is the interchange format: jax ≥ 0.5 serializes protos with
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids.

pub mod engine;
pub mod manifest;
pub mod programs;
pub mod tensor;

pub use engine::Engine;
pub use manifest::{Manifest, ProgramManifest, TensorSpec};
pub use programs::{BatchData, GradOut, ModelPrograms};
pub use tensor::HostTensor;
