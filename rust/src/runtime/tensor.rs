//! Host-side tensor values crossing the rust <-> PJRT boundary.

use anyhow::anyhow;

use crate::Result;

/// A host tensor: flat data + shape. Only the dtypes the L2 programs use.
#[derive(Debug, Clone, PartialEq)]
pub enum HostTensor {
    F32(Vec<f32>, Vec<i64>),
    I32(Vec<i32>, Vec<i64>),
}

impl HostTensor {
    pub fn scalar_i32(v: i32) -> Self {
        HostTensor::I32(vec![v], vec![])
    }

    pub fn scalar_f32(v: f32) -> Self {
        HostTensor::F32(vec![v], vec![])
    }

    pub fn f32(data: Vec<f32>, shape: &[i64]) -> Self {
        debug_assert_eq!(
            data.len() as i64,
            shape.iter().product::<i64>(),
            "data/shape mismatch"
        );
        HostTensor::F32(data, shape.to_vec())
    }

    pub fn i32(data: Vec<i32>, shape: &[i64]) -> Self {
        debug_assert_eq!(data.len() as i64, shape.iter().product::<i64>());
        HostTensor::I32(data, shape.to_vec())
    }

    pub fn shape(&self) -> &[i64] {
        match self {
            HostTensor::F32(_, s) | HostTensor::I32(_, s) => s,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            HostTensor::F32(d, _) => d.len(),
            HostTensor::I32(d, _) => d.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Byte size on the wire / in device memory.
    pub fn size_bytes(&self) -> usize {
        self.len() * 4
    }

    /// Convert to an XLA literal (copies).
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let lit = match self {
            HostTensor::F32(d, s) => {
                if s.is_empty() {
                    xla::Literal::scalar(d[0])
                } else {
                    xla::Literal::vec1(d).reshape(s).map_err(|e| anyhow!("{e:?}"))?
                }
            }
            HostTensor::I32(d, s) => {
                if s.is_empty() {
                    xla::Literal::scalar(d[0])
                } else {
                    xla::Literal::vec1(d).reshape(s).map_err(|e| anyhow!("{e:?}"))?
                }
            }
        };
        Ok(lit)
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32(d, _) => Ok(d),
            HostTensor::I32(..) => Err(anyhow!("tensor is i32, expected f32")),
        }
    }
}

/// Build an f32 literal directly from a borrowed slice (one copy into the
/// literal, no intermediate Vec). Perf-pass P2: the params buffer used to
/// be cloned into a `HostTensor` *and then* copied into the literal each
/// step — for tinygpt that was an extra 13 MiB memcpy per grad/apply call.
pub fn literal_from_f32_slice(data: &[f32], shape: &[i64]) -> Result<xla::Literal> {
    debug_assert_eq!(data.len() as i64, shape.iter().product::<i64>().max(1));
    if shape.is_empty() {
        return Ok(xla::Literal::scalar(data[0]));
    }
    xla::Literal::vec1(data)
        .reshape(shape)
        .map_err(|e| anyhow!("{e:?}"))
}

/// Extract a flat f32 vec from a literal.
pub fn literal_to_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))
}

/// Extract the first element of a scalar f32 literal.
pub fn literal_scalar_f32(lit: &xla::Literal) -> Result<f32> {
    lit.get_first_element::<f32>().map_err(|e| anyhow!("{e:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_and_bytes() {
        let t = HostTensor::f32(vec![0.0; 12], &[3, 4]);
        assert_eq!(t.shape(), &[3, 4]);
        assert_eq!(t.len(), 12);
        assert_eq!(t.size_bytes(), 48);
    }

    #[test]
    fn literal_roundtrip_f32() {
        let t = HostTensor::f32(vec![1.0, 2.0, 3.0, 4.0], &[2, 2]);
        let lit = t.to_literal().unwrap();
        assert_eq!(literal_to_f32(&lit).unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn literal_roundtrip_scalar() {
        let t = HostTensor::scalar_i32(42);
        let lit = t.to_literal().unwrap();
        assert_eq!(lit.get_first_element::<i32>().unwrap(), 42);
    }
}
