//! Typed API over one model preset's program family
//! (init / grad_step / apply_update / eval_step), matching the contract in
//! `python/compile/model.py`.

use std::sync::Arc;

use anyhow::anyhow;

use super::engine::Engine;
use super::manifest::ProgramManifest;
use super::tensor::{literal_from_f32_slice, literal_scalar_f32, literal_to_f32, HostTensor};
use crate::Result;

/// One rank's (already bucket-padded) batch: inputs in manifest order.
#[derive(Debug, Clone)]
pub struct BatchData {
    /// x (images f32 / tokens i32), y (labels/targets i32), mask (f32).
    pub tensors: Vec<HostTensor>,
    /// Number of *real* (unmasked) samples.
    pub real_samples: usize,
    /// Bucket size the tensors are padded to.
    pub bucket: usize,
}

impl BatchData {
    pub fn size_bytes(&self) -> usize {
        self.tensors.iter().map(|t| t.size_bytes()).sum()
    }
}

/// Output of one local grad step.
#[derive(Debug, Clone)]
pub struct GradOut {
    /// Flat sum-of-per-sample gradients (length = param_count).
    pub grads: Vec<f32>,
    /// Masked sum of per-sample losses.
    pub loss_sum: f32,
    /// Masked count of correct predictions (f32 for uniformity).
    pub correct: f32,
}

/// Handle to a model preset's executables, lazily compiled via [`Engine`].
pub struct ModelPrograms {
    engine: Arc<Engine>,
    name: String,
    manifest: ProgramManifest,
}

impl ModelPrograms {
    pub fn new(engine: Arc<Engine>, preset: &str) -> Result<Self> {
        let manifest = engine.manifest().program(preset)?.clone();
        Ok(Self {
            engine,
            name: preset.to_string(),
            manifest,
        })
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn manifest(&self) -> &ProgramManifest {
        &self.manifest
    }

    pub fn param_count(&self) -> usize {
        self.manifest.param_count
    }

    pub fn buckets(&self) -> &[usize] {
        &self.manifest.buckets
    }

    /// Deterministic parameter init from a scalar seed.
    pub fn init_params(&self, seed: i32) -> Result<Vec<f32>> {
        let exe = self.engine.executable(&self.manifest.files.init.file)?;
        let outs = exe.run(&[HostTensor::scalar_i32(seed)])?;
        let flat = literal_to_f32(&outs[0])?;
        if flat.len() != self.manifest.param_count {
            return Err(anyhow!(
                "init returned {} params, manifest says {}",
                flat.len(),
                self.manifest.param_count
            ));
        }
        Ok(flat)
    }

    /// Local fwd+bwd: returns summed gradients + loss/accuracy numerators.
    pub fn grad_step(&self, params: &[f32], batch: &BatchData) -> Result<GradOut> {
        let file = self
            .manifest
            .files
            .grad
            .get(&batch.bucket)
            .ok_or_else(|| anyhow!("no grad program for bucket {}", batch.bucket))?;
        let exe = self.engine.executable(&file.file)?;
        // Build literals straight from borrowed buffers (no staging Vecs).
        let mut args = vec![literal_from_f32_slice(params, &[params.len() as i64])?];
        for t in &batch.tensors {
            args.push(t.to_literal()?);
        }
        let outs = exe.run_literals(&args)?;
        Ok(GradOut {
            grads: literal_to_f32(&outs[0])?,
            loss_sum: literal_scalar_f32(&outs[1])?,
            correct: literal_scalar_f32(&outs[2])?,
        })
    }

    /// Fused SGD-momentum update (L1 Pallas kernel); `hyper` =
    /// [lr, momentum, weight_decay, grad_scale].
    pub fn apply_update(
        &self,
        params: &mut Vec<f32>,
        momentum: &mut Vec<f32>,
        grads: &[f32],
        hyper: [f32; 4],
    ) -> Result<()> {
        let exe = self.engine.executable(&self.manifest.files.apply.file)?;
        let n = params.len() as i64;
        let outs = exe.run_literals(&[
            literal_from_f32_slice(params, &[n])?,
            literal_from_f32_slice(momentum, &[n])?,
            literal_from_f32_slice(grads, &[n])?,
            literal_from_f32_slice(&hyper, &[4])?,
        ])?;
        *params = literal_to_f32(&outs[0])?;
        *momentum = literal_to_f32(&outs[1])?;
        Ok(())
    }

    /// Eval pass: (loss_sum, correct) numerators over the masked batch.
    pub fn eval_step(&self, params: &[f32], batch: &BatchData) -> Result<(f32, f32)> {
        let file = self
            .manifest
            .files
            .eval
            .get(&batch.bucket)
            .ok_or_else(|| anyhow!("no eval program for bucket {}", batch.bucket))?;
        let exe = self.engine.executable(&file.file)?;
        let mut args = vec![literal_from_f32_slice(params, &[params.len() as i64])?];
        for t in &batch.tensors {
            args.push(t.to_literal()?);
        }
        let outs = exe.run_literals(&args)?;
        Ok((literal_scalar_f32(&outs[0])?, literal_scalar_f32(&outs[1])?))
    }

    /// Warm the executable cache for a set of buckets (used by the
    /// profiler so benchmarking doesn't include compile time).
    pub fn warm(&self, buckets: &[usize]) -> Result<()> {
        self.engine.executable(&self.manifest.files.init.file)?;
        self.engine.executable(&self.manifest.files.apply.file)?;
        for b in buckets {
            if let Some(f) = self.manifest.files.grad.get(b) {
                self.engine.executable(&f.file)?;
            }
            if let Some(f) = self.manifest.files.eval.get(b) {
                self.engine.executable(&f.file)?;
            }
        }
        Ok(())
    }
}
