//! The PJRT engine: one CPU client + a cache of compiled executables.
//!
//! Executables are compiled lazily on first use (compiling every batch
//! bucket of every program up front would cost tens of seconds) and cached
//! for the life of the process. The engine is shared by all simulated
//! devices/worker threads.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, Context};

use super::manifest::Manifest;
use super::tensor::HostTensor;
use crate::Result;

/// Thread-safe wrapper around a compiled PJRT executable.
///
/// SAFETY: the `xla` crate wrappers hold raw pointers and are therefore not
/// auto-`Send`/`Sync`, but the underlying PJRT *CPU* client
/// (`TfrtCpuClient`) and its loaded executables are documented thread-safe
/// in XLA — `Execute` may be invoked concurrently from multiple threads.
/// We never expose interior mutability beyond `execute`.
pub struct SharedExecutable {
    exe: xla::PjRtLoadedExecutable,
    /// Name for diagnostics (file stem).
    pub name: String,
}

unsafe impl Send for SharedExecutable {}
unsafe impl Sync for SharedExecutable {}

impl SharedExecutable {
    /// Execute with host tensors; returns the decomposed output tuple.
    ///
    /// All L2 programs are lowered with `return_tuple=True`, so the single
    /// output literal is always a tuple (possibly of one element).
    pub fn run(&self, args: &[HostTensor]) -> Result<Vec<xla::Literal>> {
        let literals: Vec<xla::Literal> = args
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_>>()?;
        self.run_literals(&literals)
    }

    /// Execute with pre-built literals (hot path: avoids re-building
    /// literals for buffers that don't change between calls).
    pub fn run_literals(&self, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let outs = self
            .exe
            .execute::<xla::Literal>(args)
            .map_err(|e| anyhow!("PJRT execute failed for {}: {e:?}", self.name))?;
        let tuple = outs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch output of {}: {e:?}", self.name))?;
        tuple
            .to_tuple()
            .map_err(|e| anyhow!("untuple output of {}: {e:?}", self.name))
    }
}

/// PJRT engine: client + manifest + lazy executable cache.
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    dir: PathBuf,
    cache: Mutex<HashMap<String, Arc<SharedExecutable>>>,
}

// SAFETY: see SharedExecutable — the PJRT CPU client is thread-safe.
unsafe impl Send for Engine {}
unsafe impl Sync for Engine {}

impl Engine {
    /// Load the manifest and create the PJRT CPU client.
    pub fn load(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        let dir = artifacts_dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT CPU client: {e:?}"))?;
        Ok(Self {
            client,
            manifest,
            dir,
            cache: Mutex::new(HashMap::new()),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn artifacts_dir(&self) -> &Path {
        &self.dir
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Number of executables currently compiled & cached.
    pub fn cached_executables(&self) -> usize {
        self.cache.lock().unwrap().len()
    }

    /// Get (compiling + caching on first use) the executable for an
    /// artifact file name, e.g. `"mobinet_grad_b64.hlo.txt"`.
    pub fn executable(&self, file: &str) -> Result<Arc<SharedExecutable>> {
        if let Some(exe) = self.cache.lock().unwrap().get(file) {
            return Ok(exe.clone());
        }
        // Compile outside the lock: compilation can take seconds and other
        // threads may want other executables meanwhile. A duplicate compile
        // of the same file is possible but harmless (last one wins).
        let path = self.dir.join(file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parse HLO text {path:?}: {e:?}"))
        .context("run `make artifacts` if artifacts are missing/stale")?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("XLA compile of {file}: {e:?}"))?;
        let shared = Arc::new(SharedExecutable {
            exe,
            name: file.to_string(),
        });
        self.cache
            .lock()
            .unwrap()
            .insert(file.to_string(), shared.clone());
        Ok(shared)
    }
}
