//! Synthetic byte-level corpus for the e2e transformer driver.
//!
//! A seeded order-1 Markov chain over a 64-symbol alphabet, interleaved
//! with verbatim repetitions of a few fixed "phrases" — structure a small
//! LM can exploit (bigram statistics + exact phrase continuation), so the
//! loss curve visibly drops within a few hundred steps.

use crate::util::Rng;

/// Deterministic, index-addressable token stream.
#[derive(Debug, Clone)]
pub struct SynthCorpus {
    len: usize,
    vocab: usize,
    tokens: Vec<i32>,
}

impl SynthCorpus {
    /// Generate `len` tokens over `vocab` symbols from `seed`.
    pub fn new(len: usize, vocab: usize, seed: u64) -> Self {
        Self::with_salt(len, vocab, seed, 0)
    }

    /// Same *language* (identical Markov structure + phrases — both are
    /// derived from `seed` alone), different stream: `salt` only reseeds
    /// the sampling walk. Eval splits use this so held-out text tests
    /// generalization on the same distribution.
    pub fn with_salt(len: usize, vocab: usize, seed: u64, salt: u64) -> Self {
        assert!(vocab >= 8, "vocab too small");
        let mut rng = Rng::new(seed ^ 0xC0FF_u64);
        let active = vocab.min(64);
        // Sparse successor lists: each symbol prefers 4 successors.
        let successors: Vec<Vec<i32>> = (0..active)
            .map(|_| (0..4).map(|_| rng.below(active) as i32).collect())
            .collect();
        // A few fixed phrases of length 8..16.
        let phrases: Vec<Vec<i32>> = (0..6)
            .map(|_| {
                let n = 8 + rng.below(8);
                (0..n).map(|_| rng.below(active) as i32).collect()
            })
            .collect();
        // Materialize the stream (cheap: 4 bytes/token). The walk RNG is
        // salted so train/eval share structure but not text.
        let mut rng = rng.fork(salt ^ 0x57EA_u64);
        let mut tokens = Vec::with_capacity(len);
        let mut cur = 0_i32;
        while tokens.len() < len {
            if rng.next_f64() < 0.15 {
                // Emit a phrase verbatim.
                let p = &phrases[rng.below(phrases.len())];
                for &t in p {
                    if tokens.len() < len {
                        tokens.push(t);
                    }
                }
                cur = *phrases[0].first().unwrap_or(&0);
            } else {
                let succ = &successors[cur as usize % active];
                cur = if rng.next_f64() < 0.9 {
                    succ[rng.below(succ.len())]
                } else {
                    rng.below(active) as i32
                };
                tokens.push(cur);
            }
        }
        Self { len, vocab, tokens }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn vocab(&self) -> usize {
        self.vocab
    }

    /// Number of non-overlapping (seq_len+1)-token windows available.
    pub fn num_windows(&self, seq_len: usize) -> usize {
        self.len / (seq_len + 1)
    }

    /// Window `idx`: (tokens[0..T], targets = tokens[1..T+1]).
    pub fn window(&self, idx: usize, seq_len: usize) -> (Vec<i32>, Vec<i32>) {
        let start = idx * (seq_len + 1);
        assert!(
            start + seq_len + 1 <= self.len,
            "window {idx} out of range for seq_len {seq_len}"
        );
        let toks = self.tokens[start..start + seq_len].to_vec();
        let tgts = self.tokens[start + 1..start + seq_len + 1].to_vec();
        (toks, tgts)
    }

    /// Gather a set of windows.
    pub fn gather(&self, indices: &[usize], seq_len: usize) -> Vec<(Vec<i32>, Vec<i32>)> {
        indices.iter().map(|&i| self.window(i, seq_len)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let a = SynthCorpus::new(1000, 256, 5);
        let b = SynthCorpus::new(1000, 256, 5);
        assert_eq!(a.tokens, b.tokens);
        let c = SynthCorpus::new(1000, 256, 6);
        assert_ne!(a.tokens, c.tokens);
    }

    #[test]
    fn windows_shift_by_one() {
        let c = SynthCorpus::new(500, 256, 1);
        let (toks, tgts) = c.window(2, 16);
        assert_eq!(toks.len(), 16);
        assert_eq!(tgts.len(), 16);
        assert_eq!(&toks[1..], &tgts[..15], "targets are tokens shifted by 1");
    }

    #[test]
    fn tokens_in_vocab() {
        let c = SynthCorpus::new(2000, 256, 9);
        assert!(c.tokens.iter().all(|&t| (0..256).contains(&t)));
    }

    #[test]
    fn has_low_entropy_structure() {
        // Bigram distribution must be far from uniform: count distinct
        // successors of the most common symbol.
        let c = SynthCorpus::new(20_000, 256, 2);
        let mut follows = std::collections::HashMap::<i32, std::collections::HashSet<i32>>::new();
        for w in c.tokens.windows(2) {
            follows.entry(w[0]).or_default().insert(w[1]);
        }
        let avg_successors: f64 = follows.values().map(|s| s.len() as f64).sum::<f64>()
            / follows.len() as f64;
        // Uniform random would approach ~64 successors (alphabet is 64);
        // the Markov structure keeps it far lower.
        assert!(avg_successors < 40.0, "avg successors {avg_successors}");
    }

    #[test]
    fn salted_stream_same_language_different_text() {
        let train = SynthCorpus::new(5000, 256, 3);
        let eval = SynthCorpus::with_salt(5000, 256, 3, 1);
        assert_ne!(train.tokens, eval.tokens, "streams must differ");
        // Same language: bigram supports overlap heavily. Compare the
        // sets of observed bigrams.
        let bigrams = |c: &SynthCorpus| -> std::collections::HashSet<(i32, i32)> {
            c.tokens.windows(2).map(|w| (w[0], w[1])).collect()
        };
        let bt = bigrams(&train);
        let be = bigrams(&eval);
        let inter = bt.intersection(&be).count();
        let frac = inter as f64 / bt.len().max(1) as f64;
        assert!(frac > 0.5, "bigram overlap only {frac:.2}");
    }

    #[test]
    fn num_windows_accounts_for_target_shift() {
        let c = SynthCorpus::new(100, 256, 0);
        assert_eq!(c.num_windows(9), 10);
    }
}
