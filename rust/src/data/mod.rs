//! Synthetic datasets (DESIGN.md §3: CIFAR-10 is not downloadable in this
//! sandbox, so we generate learnable data deterministically).
//!
//! * [`cifar_synth::SynthCifar`] — 32×32×3, 10 classes: each class has a
//!   deterministic low-frequency prototype image; samples are prototype +
//!   Gaussian noise. Separability is controlled by `noise_std`, so models
//!   actually *learn* and the paper's accuracy-parity claim across
//!   cluster configurations is measurable.
//! * [`corpus::SynthCorpus`] — byte-level token stream from a seeded
//!   order-1 Markov chain with phrase repetition: enough structure that a
//!   small LM's loss visibly falls (the e2e transformer driver).
//!
//! Both are index-addressable and generated on the fly — no storage, no
//! I/O, any shard of any epoch is reproducible from (seed, index).

pub mod cifar_synth;
pub mod corpus;

pub use cifar_synth::SynthCifar;
pub use corpus::SynthCorpus;

use crate::runtime::{BatchData, HostTensor};

/// Assemble an image-classification batch padded to `bucket`, mask-exact.
///
/// `samples` are (image, label) pairs (image row-major 32*32*3).
pub fn image_batch(
    samples: &[(Vec<f32>, i32)],
    bucket: usize,
    image_size: usize,
) -> BatchData {
    let real = samples.len();
    assert!(real <= bucket, "bucket {bucket} too small for {real} samples");
    let pixels = image_size * image_size * 3;
    let mut x = Vec::with_capacity(bucket * pixels);
    let mut y = Vec::with_capacity(bucket);
    let mut mask = Vec::with_capacity(bucket);
    for (img, label) in samples {
        debug_assert_eq!(img.len(), pixels);
        x.extend_from_slice(img);
        y.push(*label);
        mask.push(1.0);
    }
    // Padding: zeros with mask 0 — exact no-ops under masked loss.
    x.resize(bucket * pixels, 0.0);
    y.resize(bucket, 0);
    mask.resize(bucket, 0.0);
    BatchData {
        tensors: vec![
            HostTensor::f32(x, &[bucket as i64, image_size as i64, image_size as i64, 3]),
            HostTensor::i32(y, &[bucket as i64]),
            HostTensor::f32(mask, &[bucket as i64]),
        ],
        real_samples: real,
        bucket,
    }
}

/// Assemble a language-modeling batch padded to `bucket`.
pub fn token_batch(windows: &[(Vec<i32>, Vec<i32>)], bucket: usize, seq_len: usize) -> BatchData {
    let real = windows.len();
    assert!(real <= bucket);
    let mut toks = Vec::with_capacity(bucket * seq_len);
    let mut tgts = Vec::with_capacity(bucket * seq_len);
    let mut mask = Vec::with_capacity(bucket);
    for (t, g) in windows {
        debug_assert_eq!(t.len(), seq_len);
        debug_assert_eq!(g.len(), seq_len);
        toks.extend_from_slice(t);
        tgts.extend_from_slice(g);
        mask.push(1.0);
    }
    toks.resize(bucket * seq_len, 0);
    tgts.resize(bucket * seq_len, 0);
    mask.resize(bucket, 0.0);
    BatchData {
        tensors: vec![
            HostTensor::i32(toks, &[bucket as i64, seq_len as i64]),
            HostTensor::i32(tgts, &[bucket as i64, seq_len as i64]),
            HostTensor::f32(mask, &[bucket as i64]),
        ],
        real_samples: real,
        bucket,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn image_batch_pads_and_masks() {
        let samples = vec![(vec![0.5; 32 * 32 * 3], 3_i32); 2];
        let b = image_batch(&samples, 4, 32);
        assert_eq!(b.real_samples, 2);
        assert_eq!(b.bucket, 4);
        assert_eq!(b.tensors[0].shape(), &[4, 32, 32, 3]);
        match &b.tensors[2] {
            HostTensor::F32(m, _) => assert_eq!(m, &vec![1.0, 1.0, 0.0, 0.0]),
            _ => panic!("mask dtype"),
        }
    }

    #[test]
    #[should_panic(expected = "too small")]
    fn bucket_overflow_panics() {
        let samples = vec![(vec![0.0; 32 * 32 * 3], 0_i32); 5];
        image_batch(&samples, 4, 32);
    }

    #[test]
    fn token_batch_shapes() {
        let w = vec![(vec![1_i32; 16], vec![2_i32; 16])];
        let b = token_batch(&w, 2, 16);
        assert_eq!(b.tensors[0].shape(), &[2, 16]);
        assert_eq!(b.real_samples, 1);
    }
}
