//! Synthetic CIFAR-10 stand-in: deterministic, learnable, index-addressed.
//!
//! Each of the 10 classes gets a prototype image built from a few seeded
//! low-frequency sinusoids over the 32×32 grid (so classes are visually
//! distinct patterns rather than pure noise); a sample is its class
//! prototype plus i.i.d. Gaussian pixel noise. With the default
//! `noise_std=0.6` a MobiNet-class CNN reaches high accuracy in a few
//! epochs while the task remains non-trivial.

use crate::util::Rng;

/// Deterministic synthetic image-classification dataset.
#[derive(Debug, Clone)]
pub struct SynthCifar {
    len: usize,
    seed: u64,
    /// Extra per-sample entropy; lets an eval split share the class
    /// prototypes (the *task*) while drawing disjoint samples.
    sample_salt: u64,
    noise_std: f32,
    num_classes: usize,
    image_size: usize,
    /// Per-class prototype images (class-major, row-major pixels).
    prototypes: Vec<Vec<f32>>,
}

impl SynthCifar {
    pub fn new(len: usize, seed: u64) -> Self {
        Self::with_params(len, seed, 0.6, 10, 32)
    }

    /// An evaluation split of the *same task*: identical class prototypes,
    /// disjoint sample noise (fresh `sample_salt`).
    pub fn eval_split(&self, len: usize) -> Self {
        let mut out = self.clone();
        out.len = len;
        out.sample_salt = self.sample_salt ^ 0x5EED_E7A1_u64;
        out
    }

    pub fn with_params(
        len: usize,
        seed: u64,
        noise_std: f32,
        num_classes: usize,
        image_size: usize,
    ) -> Self {
        let mut rng = Rng::new(seed ^ 0xC1FA_u64);
        let pixels = image_size * image_size * 3;
        let prototypes = (0..num_classes)
            .map(|_| {
                // 3 random sinusoid components per channel.
                let mut img = vec![0.0_f32; pixels];
                for c in 0..3 {
                    for _ in 0..3 {
                        let fx = 1.0 + rng.next_f32() * 3.0;
                        let fy = 1.0 + rng.next_f32() * 3.0;
                        let phase = rng.next_f32() * std::f32::consts::TAU;
                        let amp = 0.4 + rng.next_f32() * 0.6;
                        for yy in 0..image_size {
                            for xx in 0..image_size {
                                let v = amp
                                    * ((fx * xx as f32 / image_size as f32
                                        + fy * yy as f32 / image_size as f32)
                                        * std::f32::consts::TAU
                                        + phase)
                                        .sin();
                                img[(yy * image_size + xx) * 3 + c] += v;
                            }
                        }
                    }
                }
                img
            })
            .collect();
        Self {
            len,
            seed,
            sample_salt: 0,
            noise_std,
            num_classes,
            image_size,
            prototypes,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn image_size(&self) -> usize {
        self.image_size
    }

    pub fn num_classes(&self) -> usize {
        self.num_classes
    }

    /// Deterministic (image, label) for dataset index `idx`.
    pub fn sample(&self, idx: usize) -> (Vec<f32>, i32) {
        assert!(idx < self.len, "index {idx} out of range {}", self.len);
        let mut rng = Rng::new(
            self.seed ^ self.sample_salt ^ (idx as u64).wrapping_mul(0x9E3779B97F4A7C15),
        );
        let label = (idx % self.num_classes) as i32; // balanced classes
        let proto = &self.prototypes[label as usize];
        let img = proto
            .iter()
            .map(|&p| p + rng.normal_f32(0.0, self.noise_std))
            .collect();
        (img, label)
    }

    /// Gather samples for a set of indices.
    pub fn gather(&self, indices: &[usize]) -> Vec<(Vec<f32>, i32)> {
        indices.iter().map(|&i| self.sample(i)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_by_index() {
        let d = SynthCifar::new(100, 7);
        let (a, la) = d.sample(13);
        let (b, lb) = d.sample(13);
        assert_eq!(a, b);
        assert_eq!(la, lb);
        let (c, _) = d.sample(14);
        assert_ne!(a, c);
    }

    #[test]
    fn labels_are_balanced() {
        let d = SynthCifar::new(1000, 0);
        let mut counts = [0_usize; 10];
        for i in 0..1000 {
            counts[d.sample(i).1 as usize] += 1;
        }
        assert!(counts.iter().all(|&c| c == 100), "{counts:?}");
    }

    #[test]
    fn classes_are_separable() {
        // Distance to own prototype must be far smaller than to others
        // (sanity on learnability).
        let d = SynthCifar::new(100, 3);
        let (img, label) = d.sample(5);
        let dist = |a: &[f32], b: &[f32]| -> f32 {
            a.iter().zip(b).map(|(x, y)| (x - y).powi(2)).sum::<f32>()
        };
        let own = dist(&img, &d.prototypes[label as usize]);
        for (c, proto) in d.prototypes.iter().enumerate() {
            if c != label as usize {
                let other = dist(&img, proto);
                assert!(own < other, "class {c}: own {own} !< other {other}");
            }
        }
    }

    #[test]
    fn image_values_are_bounded() {
        let d = SynthCifar::new(10, 1);
        let (img, _) = d.sample(0);
        assert_eq!(img.len(), 32 * 32 * 3);
        assert!(img.iter().all(|v| v.is_finite() && v.abs() < 10.0));
    }

    #[test]
    fn eval_split_shares_task_but_not_samples() {
        let train = SynthCifar::new(100, 5);
        let eval = train.eval_split(50);
        // Same prototypes (same task)...
        assert_eq!(train.prototypes, eval.prototypes);
        // ...but different noise draws for the same index.
        assert_ne!(train.sample(3).0, eval.sample(3).0);
        // Labels still balanced the same way.
        assert_eq!(train.sample(3).1, eval.sample(3).1);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn out_of_range_panics() {
        SynthCifar::new(5, 0).sample(5);
    }
}
