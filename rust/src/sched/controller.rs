//! Guarded runtime rebalancing controller (paper §III-C "dynamically
//! balances tasks based on real-time performance").
//!
//! The one-shot offline benchmark gives a *static* allocation; at runtime
//! devices drift (thermal throttling, background contention — the embodied
//! deployment scenarios of §I). [`AdaptiveController`] closes the loop:
//! workers feed per-sample compute-time observations in, the controller
//! EMA-smooths them per rank, and a rebalance is applied only when every
//! guard passes:
//!
//! * **freshness** — every rank must have reported within
//!   `freshness_steps`; a rank that skipped a window is never rescored on
//!   stale data (the bug in the old inline adaptation block, which kept
//!   `adapt_times` entries forever);
//! * **cooldown** — at least `cooldown_steps` between rebalances, so the
//!   allocation cannot thrash on noise;
//! * **minimum drift** — the max relative score change must reach
//!   `min_rel_delta` (hysteresis);
//! * **shift cap** — no rank's share moves by more than `shift_cap`
//!   samples per rebalance (bounded perturbation of the data order).
//!
//! Every applied rebalance is recorded as a [`RebalanceEvent`] (old/new
//! scores and allocation, trigger reason) and surfaced in the training
//! report JSON.

use super::allocation::{cap_allocation, proportional_allocation};
use super::profiler::Profiler;
use crate::util::json::Json;

/// Guard and smoothing knobs for [`AdaptiveController`].
#[derive(Debug, Clone)]
pub struct ControllerConfig {
    /// Weight of a new observation in the per-rank EMA (0 < α ≤ 1).
    pub ema_alpha: f64,
    /// Minimum max-relative score change that justifies a rebalance.
    pub min_rel_delta: f64,
    /// Minimum steps between applied rebalances.
    pub cooldown_steps: usize,
    /// Max per-rank allocation change per rebalance in samples
    /// (0 = uncapped).
    pub shift_cap: usize,
    /// Observations older than this many steps are stale; a rebalance
    /// needs a fresh observation from *every* rank.
    pub freshness_steps: usize,
    /// Keep every rank at least this many samples (when the global batch
    /// allows), so a slow rank still produces timing observations.
    pub min_share: usize,
}

// Keep these in sync with `TrainOptions::default()` /
// `TrainOptions::controller_config()` — the trainer's knobs are the
// canonical defaults (the virtual-time bench calibrates its own copy in
// `simnet::DynamicSimConfig::paper_epoch`).
impl Default for ControllerConfig {
    fn default() -> Self {
        Self {
            ema_alpha: 0.5,
            min_rel_delta: 0.10,
            cooldown_steps: 10,
            shift_cap: 32,
            freshness_steps: 30,
            min_share: 1,
        }
    }
}

/// One applied rebalance (for the metrics JSON and the bench report).
#[derive(Debug, Clone, PartialEq)]
pub struct RebalanceEvent {
    /// Global step at which the new allocation took effect.
    pub step: usize,
    pub old_scores: Vec<f64>,
    pub new_scores: Vec<f64>,
    pub old_allocation: Vec<usize>,
    pub new_allocation: Vec<usize>,
    /// Human-readable trigger ("score-drift 23.1% >= 5.0%").
    pub reason: String,
}

impl RebalanceEvent {
    pub fn to_json(&self) -> Json {
        let nums = |v: &[f64]| Json::arr(v.iter().map(|x| Json::num(*x)).collect());
        let ints = |v: &[usize]| Json::arr(v.iter().map(|x| Json::num(*x as f64)).collect());
        Json::obj(vec![
            ("step", Json::num(self.step as f64)),
            ("old_scores", nums(&self.old_scores)),
            ("new_scores", nums(&self.new_scores)),
            ("old_allocation", ints(&self.old_allocation)),
            ("new_allocation", ints(&self.new_allocation)),
            ("reason", Json::str(self.reason.clone())),
        ])
    }
}

/// EMA-smoothed, guard-gated runtime rebalancer.
#[derive(Debug, Clone)]
pub struct AdaptiveController {
    cfg: ControllerConfig,
    world: usize,
    global_batch: usize,
    /// Largest per-device batch (compiled bucket cap).
    cap: usize,
    /// Per-rank EMA of observed per-sample compute seconds.
    ema: Vec<f64>,
    /// Step of each rank's latest observation (freshness tracking).
    last_obs: Vec<Option<usize>>,
    scores: Vec<f64>,
    allocation: Vec<usize>,
    last_rebalance: Option<usize>,
    /// Set while a shift-capped rebalance left the allocation short of its
    /// target: the next window resumes the move even without fresh drift.
    pending_move: bool,
    events: Vec<RebalanceEvent>,
}

impl AdaptiveController {
    /// Start from the offline-benchmark scores; errors if `global_batch`
    /// cannot fit `world` devices at `cap`.
    pub fn new(
        cfg: ControllerConfig,
        initial_scores: &[f64],
        global_batch: usize,
        cap: usize,
    ) -> crate::Result<Self> {
        anyhow::ensure!(!initial_scores.is_empty(), "controller needs at least one rank");
        anyhow::ensure!(
            cfg.ema_alpha > 0.0 && cfg.ema_alpha <= 1.0,
            "ema_alpha must be in (0, 1], got {}",
            cfg.ema_alpha
        );
        let allocation =
            Self::target_allocation(initial_scores, global_batch, cap, cfg.min_share)?;
        Ok(Self {
            world: initial_scores.len(),
            global_batch,
            cap,
            ema: vec![0.0; initial_scores.len()],
            last_obs: vec![None; initial_scores.len()],
            scores: initial_scores.to_vec(),
            allocation,
            last_rebalance: None,
            pending_move: false,
            events: Vec::new(),
            cfg,
        })
    }

    pub fn world(&self) -> usize {
        self.world
    }

    /// The scores currently applied (updated only when a rebalance lands).
    pub fn scores(&self) -> &[f64] {
        &self.scores
    }

    /// The allocation currently applied.
    pub fn allocation(&self) -> &[usize] {
        &self.allocation
    }

    pub fn events(&self) -> &[RebalanceEvent] {
        &self.events
    }

    pub fn take_events(&mut self) -> Vec<RebalanceEvent> {
        std::mem::take(&mut self.events)
    }

    /// Feed one per-sample compute-time observation for `rank` at `step`.
    /// Non-finite or non-positive observations are dropped.
    ///
    /// The observation source is the caller's choice: synchronous modes
    /// feed measured step times (all-reduced so every rank records
    /// identically), while `ps_async` feeds *server-observed push
    /// rates* ([`crate::ps::PsHub::load_window`]) — seconds per sample
    /// derived from gradient-push counts, so the barrier-free mode gets
    /// a load signal without adding a collective.
    pub fn record(&mut self, rank: usize, step: usize, per_sample_s: f64) {
        assert!(rank < self.world, "rank {rank} out of range");
        if !per_sample_s.is_finite() || per_sample_s <= 0.0 {
            return;
        }
        // A fresh observation after a long silence must not be blended
        // into the stale history — reset the EMA instead, so stale data
        // can never leak into a rescore through the smoothing.
        let stale = match self.last_obs[rank] {
            Some(last) => step.saturating_sub(last) > self.cfg.freshness_steps,
            None => true,
        };
        let a = self.cfg.ema_alpha;
        self.ema[rank] = if stale || self.ema[rank] == 0.0 {
            per_sample_s
        } else {
            a * per_sample_s + (1.0 - a) * self.ema[rank]
        };
        self.last_obs[rank] = Some(step);
    }

    /// The ungated score→allocation map: proportional split, bucket-capped,
    /// with every rank kept at `min_share` when the batch allows. Pure in
    /// `scores` (permutation-equivariant), used by the property tests.
    pub fn target_allocation(
        scores: &[f64],
        global_batch: usize,
        cap: usize,
        min_share: usize,
    ) -> crate::Result<Vec<usize>> {
        let mut alloc = cap_allocation(&proportional_allocation(scores, global_batch), cap)?;
        let n = alloc.len();
        if min_share > 0 && global_batch >= min_share * n {
            // Raise starved ranks to min_share, taking from the largest
            // shares. Terminates: while any rank is below min_share, some
            // donor above it must exist (Σ = B ≥ n·min_share).
            while let Some(lo) = (0..n).find(|&i| alloc[i] < min_share) {
                let donor = (0..n)
                    .filter(|&j| alloc[j] > min_share)
                    .max_by(|&a, &b| alloc[a].cmp(&alloc[b]).then(b.cmp(&a)))
                    .expect("donor exists while a rank is below min_share");
                alloc[lo] += 1;
                alloc[donor] -= 1;
            }
        }
        Ok(alloc)
    }

    /// Evaluate the guards at `step`; apply and record a rebalance if they
    /// all pass. Returns the event when one landed.
    pub fn maybe_rebalance(&mut self, step: usize) -> crate::Result<Option<&RebalanceEvent>> {
        // Guard 1: cooldown.
        if let Some(last) = self.last_rebalance {
            if step.saturating_sub(last) < self.cfg.cooldown_steps {
                return Ok(None);
            }
        }
        // Guard 2: freshness — every rank must have a recent observation.
        // (The old inline adaptation let a rank's entry persist across
        // windows forever, silently rescoring on stale data.)
        for obs in &self.last_obs {
            match obs {
                Some(s) if step.saturating_sub(*s) <= self.cfg.freshness_steps => {}
                _ => return Ok(None),
            }
        }
        let new_scores = Profiler::scores_from_times(&self.ema);
        // Guard 3: hysteresis on score drift — unless a shift-capped move
        // is still pending, in which case we keep walking to its target.
        let max_delta = self
            .scores
            .iter()
            .zip(&new_scores)
            .map(|(o, n)| (n - o).abs() / o.abs().max(1e-12))
            .fold(0.0, f64::max);
        let drifted = max_delta >= self.cfg.min_rel_delta;
        if !drifted && !self.pending_move {
            return Ok(None);
        }
        let target =
            Self::target_allocation(&new_scores, self.global_batch, self.cap, self.cfg.min_share)?;
        // Guard 4: bounded per-rank shift.
        let new_alloc = clamp_shift(&self.allocation, &target, self.cfg.shift_cap, self.cap);
        if new_alloc == self.allocation {
            self.pending_move = false;
            return Ok(None);
        }
        self.events.push(RebalanceEvent {
            step,
            old_scores: self.scores.clone(),
            new_scores: new_scores.clone(),
            old_allocation: self.allocation.clone(),
            new_allocation: new_alloc.clone(),
            reason: if drifted {
                format!(
                    "score-drift {:.1}% >= {:.1}%",
                    max_delta * 100.0,
                    self.cfg.min_rel_delta * 100.0
                )
            } else {
                "resume shift-capped move".to_string()
            },
        });
        self.pending_move = new_alloc != target;
        self.scores = new_scores;
        self.allocation = new_alloc;
        self.last_rebalance = Some(step);
        Ok(self.events.last())
    }
}

/// Move `current` toward `target` with each rank's change bounded by
/// `shift_cap` samples, preserving the total and the per-rank `cap`.
///
/// Feasibility: `current` itself lies inside every clamp window, so the
/// deterministic repair loops can always restore the total.
fn clamp_shift(current: &[usize], target: &[usize], shift_cap: usize, cap: usize) -> Vec<usize> {
    if shift_cap == 0 {
        return target.to_vec();
    }
    let lo: Vec<usize> = current.iter().map(|&c| c.saturating_sub(shift_cap)).collect();
    let hi: Vec<usize> = current.iter().map(|&c| (c + shift_cap).min(cap)).collect();
    let mut out: Vec<usize> = target
        .iter()
        .zip(lo.iter().zip(&hi))
        .map(|(&t, (&l, &h))| t.clamp(l, h))
        .collect();
    let total: usize = current.iter().sum();
    let mut sum: usize = out.iter().sum();
    // Repair toward the target: give to the rank furthest below its
    // target (ties → lowest index), take from the rank furthest above.
    while sum < total {
        let Some(i) = (0..out.len())
            .filter(|&i| out[i] < hi[i])
            .max_by_key(|&i| (target[i] as i64 - out[i] as i64, std::cmp::Reverse(i)))
        else {
            break;
        };
        out[i] += 1;
        sum += 1;
    }
    while sum > total {
        let Some(i) = (0..out.len())
            .filter(|&i| out[i] > lo[i])
            .max_by_key(|&i| (out[i] as i64 - target[i] as i64, std::cmp::Reverse(i)))
        else {
            break;
        };
        out[i] -= 1;
        sum -= 1;
    }
    debug_assert_eq!(out.iter().sum::<usize>(), total);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check_default;
    use crate::util::Rng;

    fn quick_cfg() -> ControllerConfig {
        ControllerConfig {
            ema_alpha: 1.0, // no smoothing: tests control the signal exactly
            min_rel_delta: 0.05,
            cooldown_steps: 10,
            shift_cap: 0,
            freshness_steps: 5,
            min_share: 1,
        }
    }

    /// Record one observation per rank at `step`.
    fn observe(ctl: &mut AdaptiveController, step: usize, per_sample: &[f64]) {
        for (r, &t) in per_sample.iter().enumerate() {
            ctl.record(r, step, t);
        }
    }

    #[test]
    fn initial_allocation_is_proportional() {
        let ctl = AdaptiveController::new(quick_cfg(), &[0.5, 1.0], 30, 128).unwrap();
        assert_eq!(ctl.allocation(), &[10, 20]);
        assert!(ctl.events().is_empty());
    }

    #[test]
    fn rebalance_follows_measured_drift() {
        let mut ctl = AdaptiveController::new(quick_cfg(), &[1.0, 1.0], 100, 128).unwrap();
        assert_eq!(ctl.allocation(), &[50, 50]);
        // Rank 0 measures 3x slower per sample.
        observe(&mut ctl, 4, &[0.3e-3, 0.1e-3]);
        let ev = ctl.maybe_rebalance(4).unwrap().cloned().expect("should rebalance");
        assert_eq!(ev.new_allocation, vec![25, 75]);
        assert_eq!(ev.old_allocation, vec![50, 50]);
        assert_eq!(ctl.allocation(), &[25, 75]);
        assert!((ctl.scores()[0] - 1.0 / 3.0).abs() < 1e-9, "{:?}", ctl.scores());
        assert!(ev.reason.contains("score-drift"));
    }

    #[test]
    fn cooldown_blocks_back_to_back_rebalances() {
        let mut ctl = AdaptiveController::new(quick_cfg(), &[1.0, 1.0], 100, 128).unwrap();
        observe(&mut ctl, 4, &[0.3e-3, 0.1e-3]);
        assert!(ctl.maybe_rebalance(4).unwrap().is_some());
        // Strong reverse drift immediately after: still inside cooldown.
        observe(&mut ctl, 8, &[0.1e-3, 0.3e-3]);
        assert!(ctl.maybe_rebalance(8).unwrap().is_none(), "cooldown must gate");
        // After the cooldown it lands.
        observe(&mut ctl, 14, &[0.1e-3, 0.3e-3]);
        assert!(ctl.maybe_rebalance(14).unwrap().is_some());
        assert_eq!(ctl.events().len(), 2);
    }

    #[test]
    fn small_drift_is_hysteresis_filtered() {
        let mut ctl = AdaptiveController::new(quick_cfg(), &[1.0, 1.0], 100, 128).unwrap();
        // 2% drift < 5% threshold.
        observe(&mut ctl, 4, &[0.102e-3, 0.1e-3]);
        assert!(ctl.maybe_rebalance(4).unwrap().is_none());
        assert_eq!(ctl.allocation(), &[50, 50]);
    }

    #[test]
    fn shift_cap_bounds_each_rebalance() {
        let cfg = ControllerConfig {
            shift_cap: 8,
            ..quick_cfg()
        };
        let mut ctl = AdaptiveController::new(cfg, &[1.0, 1.0], 100, 128).unwrap();
        observe(&mut ctl, 4, &[0.5e-3, 0.1e-3]); // target would be [17, 83]
        let ev = ctl.maybe_rebalance(4).unwrap().cloned().unwrap();
        assert_eq!(ev.new_allocation, vec![42, 58], "move clamped to ±8");
        assert_eq!(ev.new_allocation.iter().sum::<usize>(), 100);
        // The clamped move resumes after the cooldown even though the
        // applied scores already match the measurement (no fresh drift).
        observe(&mut ctl, 14, &[0.5e-3, 0.1e-3]);
        let ev2 = ctl.maybe_rebalance(14).unwrap().cloned().unwrap();
        assert_eq!(ev2.new_allocation, vec![34, 66]);
        assert!(ev2.reason.contains("resume"));
        // Walks all the way to the proportional target [17, 83], then holds.
        for w in 2..10 {
            observe(&mut ctl, 4 + 10 * w, &[0.5e-3, 0.1e-3]);
            ctl.maybe_rebalance(4 + 10 * w).unwrap();
        }
        assert_eq!(ctl.allocation(), &[17, 83]);
        let settled = ctl.events().len();
        observe(&mut ctl, 104, &[0.5e-3, 0.1e-3]);
        assert!(ctl.maybe_rebalance(104).unwrap().is_none(), "must hold at target");
        assert_eq!(ctl.events().len(), settled);
    }

    #[test]
    fn stale_rank_blocks_rescoring_regression() {
        // Regression for the stale-timing hole: the old inline adaptation
        // kept per-rank entries forever, so a rank that skipped a window
        // was rescored on stale data. The controller must refuse instead.
        let mut ctl = AdaptiveController::new(quick_cfg(), &[1.0, 1.0], 100, 128).unwrap();
        observe(&mut ctl, 2, &[0.1e-3, 0.1e-3]);
        // Only rank 0 keeps reporting; rank 1's entry ages out
        // (freshness_steps = 5).
        ctl.record(0, 20, 0.4e-3);
        assert!(
            ctl.maybe_rebalance(20).unwrap().is_none(),
            "stale rank-1 data must not be rescored"
        );
        assert_eq!(ctl.allocation(), &[50, 50]);
        // Once rank 1 reports again, the same drift lands.
        ctl.record(1, 24, 0.1e-3);
        ctl.record(0, 24, 0.4e-3);
        assert!(ctl.maybe_rebalance(24).unwrap().is_some());
    }

    #[test]
    fn stale_history_is_reset_not_blended() {
        // With real smoothing (α = 0.5), an observation arriving after a
        // long silence must replace the stale EMA, not average with it —
        // otherwise stale data would leak into the rescore through the
        // smoothing even though the freshness guard passed.
        let cfg = ControllerConfig {
            ema_alpha: 0.5,
            ..quick_cfg()
        };
        let mut ctl = AdaptiveController::new(cfg, &[1.0, 1.0], 100, 128).unwrap();
        observe(&mut ctl, 2, &[0.1e-3, 0.1e-3]);
        // 30 silent steps (> freshness 5), then both ranks report again:
        // rank 0 now runs 4x slower.
        observe(&mut ctl, 32, &[0.4e-3, 0.1e-3]);
        let ev = ctl.maybe_rebalance(32).unwrap().cloned().expect("rebalance");
        // Blending would give ema0 = 0.25e-3 (score 0.4); the reset gives
        // ema0 = 0.4e-3 (score 0.25) — the allocation must reflect the
        // fresh measurement alone.
        assert_eq!(ev.new_allocation, vec![20, 80], "{:?}", ctl.scores());
    }

    #[test]
    fn no_observations_never_rebalances() {
        let mut ctl = AdaptiveController::new(quick_cfg(), &[0.7, 1.0], 100, 128).unwrap();
        for step in 0..50 {
            assert!(ctl.maybe_rebalance(step).unwrap().is_none());
        }
        assert!(ctl.events().is_empty());
    }

    #[test]
    fn min_share_keeps_slow_rank_observable() {
        let alloc = AdaptiveController::target_allocation(&[0.001, 1.0, 1.0], 90, 64, 1).unwrap();
        assert_eq!(alloc.iter().sum::<usize>(), 90);
        assert!(alloc[0] >= 1, "starved rank must keep one sample: {alloc:?}");
    }

    #[test]
    fn clamp_shift_noop_and_exact_cases() {
        assert_eq!(clamp_shift(&[50, 50], &[30, 70], 0, 128), vec![30, 70]);
        assert_eq!(clamp_shift(&[50, 50], &[30, 70], 5, 128), vec![45, 55]);
        assert_eq!(clamp_shift(&[50, 50], &[50, 50], 5, 128), vec![50, 50]);
        // Cap binds the upward window.
        assert_eq!(clamp_shift(&[120, 8], &[60, 68], 16, 128), vec![104, 24]);
    }

    // ------------------------------------------------------------------
    // properties
    // ------------------------------------------------------------------

    #[test]
    fn prop_emitted_allocations_sum_and_respect_cap() {
        check_default(
            "controller-sum-cap",
            |rng| {
                let n = 2 + rng.below(6);
                let batch = 32 + rng.below(480);
                let cap = crate::util::cdiv(batch, n) + 1 + rng.below(96);
                let scores: Vec<f64> = (0..n).map(|_| 0.1 + rng.next_f64()).collect();
                let windows: Vec<Vec<f64>> = (0..6)
                    .map(|_| (0..n).map(|_| 1e-4 * (0.2 + rng.next_f64())).collect())
                    .collect();
                let shift_cap = rng.below(3) * (4 + rng.below(28));
                (scores, batch, cap, shift_cap, windows)
            },
            |(scores, batch, cap, shift_cap, windows)| {
                let cfg = ControllerConfig {
                    ema_alpha: 0.5,
                    min_rel_delta: 0.02,
                    cooldown_steps: 1,
                    shift_cap: *shift_cap,
                    freshness_steps: 10,
                    min_share: 1,
                };
                let mut ctl = AdaptiveController::new(cfg, scores, *batch, *cap)
                    .map_err(|e| e.to_string())?;
                for (w, times) in windows.iter().enumerate() {
                    let step = (w + 1) * 5;
                    for (r, &t) in times.iter().enumerate() {
                        ctl.record(r, step, t);
                    }
                    ctl.maybe_rebalance(step).map_err(|e| e.to_string())?;
                    let alloc = ctl.allocation();
                    if alloc.iter().sum::<usize>() != *batch {
                        return Err(format!("sum {} != {batch}", alloc.iter().sum::<usize>()));
                    }
                    if alloc.iter().any(|&b| b > *cap) {
                        return Err(format!("cap {cap} violated: {alloc:?}"));
                    }
                }
                for ev in ctl.events() {
                    let max_shift = ev
                        .old_allocation
                        .iter()
                        .zip(&ev.new_allocation)
                        .map(|(&o, &n)| o.abs_diff(n))
                        .max()
                        .unwrap_or(0);
                    if *shift_cap > 0 && max_shift > *shift_cap {
                        return Err(format!("shift {max_shift} > cap {shift_cap}"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_target_allocation_is_permutation_equivariant() {
        check_default(
            "controller-permutation",
            |rng| {
                let n = 2 + rng.below(6);
                let batch = 32 + rng.below(480);
                let tight_cap = crate::util::cdiv(batch, n) + 1 + rng.below(96);
                // Continuous random scores: exact remainder ties (the only
                // source of order dependence in the proportional map) have
                // measure zero.
                let scores: Vec<f64> = (0..n).map(|_| 0.1 + rng.next_f64()).collect();
                let mut perm: Vec<usize> = (0..n).collect();
                let mut prng = Rng::new(rng.next_u64());
                prng.shuffle(&mut perm);
                (scores, batch, tight_cap, perm)
            },
            |(scores, batch, tight_cap, perm)| {
                let permuted_scores: Vec<f64> = perm.iter().map(|&i| scores[i]).collect();
                // Exact equivariance for the proportional map (cap and
                // min_share inactive).
                let base = AdaptiveController::target_allocation(scores, *batch, *batch, 0)
                    .map_err(|e| e.to_string())?;
                let permuted =
                    AdaptiveController::target_allocation(&permuted_scores, *batch, *batch, 0)
                        .map_err(|e| e.to_string())?;
                let expect: Vec<usize> = perm.iter().map(|&i| base[i]).collect();
                if permuted != expect {
                    return Err(format!(
                        "perm {perm:?}: got {permuted:?}, want {expect:?} (base {base:?})"
                    ));
                }
                // Cap clamping and min_share repair break exact ties by
                // rank index, so there the guarantee is multiset-level:
                // the same shares get handed out, to equivalently-scored
                // ranks.
                let mut a =
                    AdaptiveController::target_allocation(scores, *batch, *tight_cap, 1)
                        .map_err(|e| e.to_string())?;
                let mut b = AdaptiveController::target_allocation(
                    &permuted_scores,
                    *batch,
                    *tight_cap,
                    1,
                )
                .map_err(|e| e.to_string())?;
                a.sort_unstable();
                b.sort_unstable();
                if a != b {
                    return Err(format!("capped multisets differ: {a:?} vs {b:?}"));
                }
                Ok(())
            },
        );
    }
}
