//! Load-adaptive scheduling — the paper's second contribution
//! (Section III-C).
//!
//! In synchronous data-parallel training the step pace is set by the
//! slowest worker; heterogeneous devices therefore need workload shares
//! proportional to their effective speed. KAITIAN:
//!
//! 1. benchmarks each device with a few timed fwd/bwd passes
//!    ([`profiler::Profiler`]), scoring the fastest 1.0 and others
//!    `score_i = t_fastest / t_i`;
//! 2. splits each global mini-batch proportionally
//!    ([`allocation::proportional_allocation`]):
//!    `b_i = score_i / Σ score_j · B`, integer-rounded with the
//!    largest-remainder method so `Σ b_i = B` exactly;
//! 3. the per-rank sampler ([`sampler::KaitianSampler`]) turns the
//!    allocation into dataset index ranges.
//!
//! [`strategy::Strategy`] also provides the Fig-3 baselines: naive equal
//! split (A) and a fixed wrong-way ratio (C).
//!
//! At runtime, [`controller::AdaptiveController`] closes the loop: it
//! EMA-smooths measured per-rank step times and applies guarded
//! rebalances (cooldown, hysteresis, shift cap, per-entry freshness) so
//! the allocation tracks load drift without thrashing — the paper's
//! "dynamically balances tasks based on real-time performance".

pub mod allocation;
pub mod controller;
pub mod profiler;
pub mod sampler;
pub mod strategy;

pub use allocation::{cap_allocation, proportional_allocation};
pub use controller::{AdaptiveController, ControllerConfig, RebalanceEvent};
pub use profiler::Profiler;
pub use sampler::KaitianSampler;
pub use strategy::Strategy;
