//! Proportional batch allocation with exact global-batch preservation.

/// Split `global_batch` across devices proportionally to `scores`
/// (paper Eq. in §III-C), using the largest-remainder method so that
/// `Σ b_i == global_batch` exactly.
///
/// Zero/negative scores get zero samples. If all scores are zero the
/// batch is split as evenly as possible (degenerate but total-preserving).
pub fn proportional_allocation(scores: &[f64], global_batch: usize) -> Vec<usize> {
    let n = scores.len();
    if n == 0 {
        return vec![];
    }
    let clamped: Vec<f64> = scores.iter().map(|s| s.max(0.0)).collect();
    let total: f64 = clamped.iter().sum();
    if total <= 0.0 {
        // Degenerate: even split.
        let base = global_batch / n;
        let extra = global_batch % n;
        return (0..n).map(|i| base + usize::from(i < extra)).collect();
    }

    // Ideal (real-valued) shares, floored; distribute the remainder to the
    // largest fractional parts (ties broken by lower index for
    // determinism).
    let ideal: Vec<f64> = clamped
        .iter()
        .map(|s| s / total * global_batch as f64)
        .collect();
    let mut alloc: Vec<usize> = ideal.iter().map(|x| x.floor() as usize).collect();
    let assigned: usize = alloc.iter().sum();
    let mut remainder: Vec<(usize, f64)> = ideal
        .iter()
        .enumerate()
        .map(|(i, x)| (i, x - x.floor()))
        .collect();
    remainder.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));
    for k in 0..global_batch - assigned {
        alloc[remainder[k % n].0] += 1;
    }
    debug_assert_eq!(alloc.iter().sum::<usize>(), global_batch);
    alloc
}

/// Clamp each device's allocation to `cap` (the largest compiled batch
/// bucket), redistributing the excess to devices with headroom while
/// preserving the total. Errors if the total cannot fit (`Σ > cap·n`).
///
/// Redistribution follows the original proportions: devices that were
/// assigned more keep receiving the excess first.
pub fn cap_allocation(alloc: &[usize], cap: usize) -> crate::Result<Vec<usize>> {
    let total: usize = alloc.iter().sum();
    anyhow::ensure!(
        total <= cap * alloc.len(),
        "global batch {total} cannot fit {} devices at max bucket {cap} — \
         lower the global batch or lower `aot.py` bucket coverage",
        alloc.len()
    );
    let mut out: Vec<usize> = alloc.iter().map(|&b| b.min(cap)).collect();
    let mut excess = total - out.iter().sum::<usize>();
    // Hand excess to devices with headroom, largest original share first
    // (deterministic: ties by index).
    let mut order: Vec<usize> = (0..alloc.len()).collect();
    order.sort_by(|&a, &b| alloc[b].cmp(&alloc[a]).then(a.cmp(&b)));
    while excess > 0 {
        let mut moved = false;
        for &i in &order {
            if excess == 0 {
                break;
            }
            if out[i] < cap {
                out[i] += 1;
                excess -= 1;
                moved = true;
            }
        }
        debug_assert!(moved, "headroom exists by the ensure above");
        if !moved {
            break;
        }
    }
    debug_assert_eq!(out.iter().sum::<usize>(), total);
    Ok(out)
}

/// The per-device share as a fraction of the global batch.
pub fn shares(alloc: &[usize]) -> Vec<f64> {
    let total: usize = alloc.iter().sum();
    if total == 0 {
        return vec![0.0; alloc.len()];
    }
    alloc.iter().map(|&b| b as f64 / total as f64).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check_default;

    #[test]
    fn equal_scores_even_split() {
        assert_eq!(proportional_allocation(&[1.0, 1.0], 256), vec![128, 128]);
        assert_eq!(
            proportional_allocation(&[1.0, 1.0, 1.0, 1.0], 256),
            vec![64, 64, 64, 64]
        );
    }

    #[test]
    fn paper_example_gpu_mlu() {
        // GPU score 0.7, MLU score 1.0 (MLU ≈ 1.42x faster): the MLU gets
        // ~59% of the batch.
        let alloc = proportional_allocation(&[0.7, 1.0], 256);
        assert_eq!(alloc.iter().sum::<usize>(), 256);
        assert_eq!(alloc, vec![105, 151]);
    }

    #[test]
    fn rounding_preserves_total_exactly() {
        let alloc = proportional_allocation(&[1.0, 1.0, 1.0], 256);
        assert_eq!(alloc.iter().sum::<usize>(), 256);
        // 256/3 = 85.33: two get 85, one gets 86.
        let mut sorted = alloc.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, vec![85, 85, 86]);
    }

    #[test]
    fn zero_score_devices_starve() {
        let alloc = proportional_allocation(&[1.0, 0.0, 1.0], 100);
        assert_eq!(alloc[1], 0);
        assert_eq!(alloc.iter().sum::<usize>(), 100);
    }

    #[test]
    fn all_zero_scores_fall_back_to_even() {
        let alloc = proportional_allocation(&[0.0, 0.0, 0.0], 10);
        assert_eq!(alloc, vec![4, 3, 3]);
    }

    #[test]
    fn empty_devices() {
        assert!(proportional_allocation(&[], 256).is_empty());
    }

    #[test]
    fn batch_smaller_than_world() {
        let alloc = proportional_allocation(&[1.0, 1.0, 1.0, 1.0], 2);
        assert_eq!(alloc.iter().sum::<usize>(), 2);
    }

    #[test]
    fn cap_redistributes_excess() {
        // 19/13 with cap 16 -> 16/16.
        let capped = cap_allocation(&[13, 19], 16).unwrap();
        assert_eq!(capped.iter().sum::<usize>(), 32);
        assert!(capped.iter().all(|&b| b <= 16));
        assert_eq!(capped, vec![16, 16]);
    }

    #[test]
    fn cap_noop_when_under() {
        assert_eq!(cap_allocation(&[5, 7], 16).unwrap(), vec![5, 7]);
    }

    #[test]
    fn cap_infeasible_total_errors() {
        assert!(cap_allocation(&[20, 20], 16).is_err());
    }

    #[test]
    fn prop_cap_preserves_total_and_bound() {
        check_default(
            "cap-alloc",
            |rng| {
                let n = 1 + rng.below(8);
                let cap = 8 + rng.below(64);
                // Feasible totals only.
                let total = rng.below(cap * n + 1);
                let alloc = proportional_allocation(
                    &(0..n).map(|_| 0.1 + rng.next_f64()).collect::<Vec<_>>(),
                    total,
                );
                (alloc, cap)
            },
            |(alloc, cap)| {
                let capped = cap_allocation(alloc, *cap).map_err(|e| e.to_string())?;
                if capped.iter().sum::<usize>() != alloc.iter().sum::<usize>() {
                    return Err("total changed".into());
                }
                if capped.iter().any(|&b| b > *cap) {
                    return Err("cap violated".into());
                }
                Ok(())
            },
        );
    }

    // ------------------------------------------------------------------
    // properties (invariants from DESIGN.md §5)
    // ------------------------------------------------------------------

    #[test]
    fn prop_sum_always_equals_global_batch() {
        check_default(
            "alloc-sum",
            |rng| {
                let n = 1 + rng.below(16);
                let scores: Vec<f64> = (0..n).map(|_| rng.next_f64() * 2.0).collect();
                let batch = rng.below(1024);
                (scores, batch)
            },
            |(scores, batch)| {
                let alloc = proportional_allocation(scores, *batch);
                if alloc.iter().sum::<usize>() == *batch {
                    Ok(())
                } else {
                    Err(format!("sum {} != batch {batch}", alloc.iter().sum::<usize>()))
                }
            },
        );
    }

    #[test]
    fn prop_allocation_close_to_ideal() {
        // |b_i - ideal_i| < 1 for the largest-remainder method.
        check_default(
            "alloc-near-ideal",
            |rng| {
                let n = 1 + rng.below(8);
                let scores: Vec<f64> = (0..n).map(|_| 0.1 + rng.next_f64()).collect();
                let batch = 1 + rng.below(512);
                (scores, batch)
            },
            |(scores, batch)| {
                let alloc = proportional_allocation(scores, *batch);
                let total: f64 = scores.iter().sum();
                for (i, &b) in alloc.iter().enumerate() {
                    let ideal = scores[i] / total * *batch as f64;
                    if (b as f64 - ideal).abs() >= 1.0 {
                        return Err(format!("b[{i}]={b} vs ideal {ideal:.3}"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_monotone_in_score() {
        // A strictly higher score never gets a smaller share.
        check_default(
            "alloc-monotone",
            |rng| {
                let n = 2 + rng.below(8);
                let scores: Vec<f64> = (0..n).map(|_| 0.05 + rng.next_f64()).collect();
                (scores, 64 + rng.below(512))
            },
            |(scores, batch)| {
                let alloc = proportional_allocation(scores, *batch);
                for i in 0..scores.len() {
                    for j in 0..scores.len() {
                        if scores[i] > scores[j] && alloc[i] < alloc[j] {
                            return Err(format!(
                                "score[{i}]={:.3} > score[{j}]={:.3} but b {} < {}",
                                scores[i], scores[j], alloc[i], alloc[j]
                            ));
                        }
                    }
                }
                Ok(())
            },
        );
    }
}
