//! Batch-split strategies (Fig. 3): KAITIAN's adaptive split vs the
//! naive and fixed baselines.

use super::allocation::proportional_allocation;

/// How the global mini-batch is split across devices each step.
#[derive(Debug, Clone, PartialEq)]
pub enum Strategy {
    /// Strategy B (KAITIAN): proportional to measured scores.
    Adaptive,
    /// Strategy A: naive equal split, ignoring device speed.
    Equal,
    /// Strategy C: a fixed ratio (e.g. a stale or wrong-way-around guess);
    /// weights are normalized internally.
    Fixed(Vec<f64>),
}

impl Strategy {
    /// Compute per-device batch sizes for one step.
    pub fn allocate(&self, scores: &[f64], global_batch: usize) -> Vec<usize> {
        match self {
            Strategy::Adaptive => proportional_allocation(scores, global_batch),
            Strategy::Equal => {
                let ones = vec![1.0; scores.len()];
                proportional_allocation(&ones, global_batch)
            }
            Strategy::Fixed(weights) => {
                assert_eq!(
                    weights.len(),
                    scores.len(),
                    "fixed strategy weight count must match device count"
                );
                proportional_allocation(weights, global_batch)
            }
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Strategy::Adaptive => "adaptive",
            Strategy::Equal => "equal",
            Strategy::Fixed(_) => "fixed",
        }
    }

    /// Parse from CLI text: "adaptive" | "equal" | "fixed:0.5,0.5".
    pub fn parse(text: &str) -> crate::Result<Strategy> {
        if text == "adaptive" {
            Ok(Strategy::Adaptive)
        } else if text == "equal" {
            Ok(Strategy::Equal)
        } else if let Some(ws) = text.strip_prefix("fixed:") {
            let weights: Vec<f64> = ws
                .split(',')
                .map(|w| w.trim().parse::<f64>())
                .collect::<Result<_, _>>()?;
            Ok(Strategy::Fixed(weights))
        } else {
            anyhow::bail!("unknown strategy {text:?} (adaptive|equal|fixed:w1,w2,...)")
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adaptive_follows_scores() {
        let s = Strategy::Adaptive;
        let alloc = s.allocate(&[0.7, 1.0], 256);
        assert!(alloc[1] > alloc[0]);
        assert_eq!(alloc.iter().sum::<usize>(), 256);
    }

    #[test]
    fn equal_ignores_scores() {
        let s = Strategy::Equal;
        assert_eq!(s.allocate(&[0.1, 0.9], 100), vec![50, 50]);
    }

    #[test]
    fn fixed_uses_weights_not_scores() {
        let s = Strategy::Fixed(vec![3.0, 1.0]);
        assert_eq!(s.allocate(&[1.0, 1.0], 100), vec![75, 25]);
    }

    #[test]
    fn parse_all_forms() {
        assert_eq!(Strategy::parse("adaptive").unwrap(), Strategy::Adaptive);
        assert_eq!(Strategy::parse("equal").unwrap(), Strategy::Equal);
        assert_eq!(
            Strategy::parse("fixed:0.3,0.7").unwrap(),
            Strategy::Fixed(vec![0.3, 0.7])
        );
        assert!(Strategy::parse("bogus").is_err());
    }

    #[test]
    #[should_panic(expected = "weight count")]
    fn fixed_wrong_arity_panics() {
        Strategy::Fixed(vec![1.0]).allocate(&[1.0, 1.0], 10);
    }
}
