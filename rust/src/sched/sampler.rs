//! KaitianDistributedSampler: per-rank dataset index assignment under
//! unequal (score-proportional) batch shares.
//!
//! Mirrors the paper's `KaitianDistributedSampler` override of PyTorch's
//! `DistributedSampler`: given the per-device allocation for a step, each
//! rank gets a disjoint, contiguous slice of the (deterministically
//! shuffled) global sample sequence. Epoch boundaries reshuffle.

use crate::util::Rng;

/// Deterministic epoch-shuffled sampler over `dataset_len` samples.
#[derive(Debug, Clone)]
pub struct KaitianSampler {
    dataset_len: usize,
    global_batch: usize,
    seed: u64,
}

impl KaitianSampler {
    pub fn new(dataset_len: usize, global_batch: usize, seed: u64) -> Self {
        assert!(dataset_len > 0 && global_batch > 0);
        Self {
            dataset_len,
            global_batch,
            seed,
        }
    }

    /// Number of full steps per epoch (drop-last semantics, like the
    /// paper's 196 steps/epoch for CIFAR-10 @ B=256).
    pub fn steps_per_epoch(&self) -> usize {
        self.dataset_len / self.global_batch
    }

    /// The shuffled global index sequence for an epoch.
    fn epoch_perm(&self, epoch: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..self.dataset_len).collect();
        let mut rng = Rng::new(self.seed ^ (epoch as u64).wrapping_mul(0x9E3779B97F4A7C15));
        rng.shuffle(&mut idx);
        idx
    }

    /// Per-rank indices for `(epoch, step)` under `allocation`
    /// (`allocation[r]` = rank r's batch share; `Σ = global_batch`).
    ///
    /// Returns one `Vec<usize>` of dataset indices per rank; slices are
    /// disjoint and together cover exactly the step's global batch.
    pub fn step_indices(
        &self,
        epoch: usize,
        step: usize,
        allocation: &[usize],
    ) -> Vec<Vec<usize>> {
        assert_eq!(
            allocation.iter().sum::<usize>(),
            self.global_batch,
            "allocation must sum to the global batch"
        );
        assert!(step < self.steps_per_epoch(), "step out of range");
        let perm = self.epoch_perm(epoch);
        let base = step * self.global_batch;
        let mut out = Vec::with_capacity(allocation.len());
        let mut off = base;
        for &b in allocation {
            out.push(perm[off..off + b].to_vec());
            off += b;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check_default;

    #[test]
    fn steps_per_epoch_matches_paper() {
        // CIFAR-10: 50_000 train samples, B=256 → 195 full steps
        // (paper's "196" rounds up; we use drop-last).
        let s = KaitianSampler::new(50_000, 256, 0);
        assert_eq!(s.steps_per_epoch(), 195);
    }

    #[test]
    fn slices_are_disjoint_and_cover_batch() {
        let s = KaitianSampler::new(1000, 64, 7);
        let alloc = vec![20, 30, 14];
        let per_rank = s.step_indices(0, 3, &alloc);
        assert_eq!(per_rank.len(), 3);
        let mut all: Vec<usize> = per_rank.iter().flatten().copied().collect();
        assert_eq!(all.len(), 64);
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), 64, "indices must be disjoint");
    }

    #[test]
    fn epochs_reshuffle_deterministically() {
        let s = KaitianSampler::new(100, 10, 42);
        let a = s.step_indices(0, 0, &[10]);
        let b = s.step_indices(0, 0, &[10]);
        assert_eq!(a, b, "same (epoch, step) must be reproducible");
        let c = s.step_indices(1, 0, &[10]);
        assert_ne!(a, c, "different epochs must differ");
    }

    #[test]
    fn no_overlap_across_steps_within_epoch() {
        let s = KaitianSampler::new(200, 50, 1);
        let s0: Vec<usize> = s.step_indices(0, 0, &[25, 25]).concat();
        let s1: Vec<usize> = s.step_indices(0, 1, &[25, 25]).concat();
        for i in &s0 {
            assert!(!s1.contains(i), "step batches within an epoch overlap");
        }
    }

    #[test]
    #[should_panic(expected = "allocation must sum")]
    fn wrong_allocation_sum_panics() {
        let s = KaitianSampler::new(100, 10, 0);
        s.step_indices(0, 0, &[3, 3]);
    }

    #[test]
    fn prop_every_epoch_is_a_permutation() {
        check_default(
            "sampler-perm",
            |rng| (1 + rng.below(500), rng.next_u64(), rng.below(10)),
            |(len, seed, epoch)| {
                let s = KaitianSampler::new(*len, 1, *seed);
                let perm = s.epoch_perm(*epoch);
                let mut sorted = perm.clone();
                sorted.sort_unstable();
                if sorted == (0..*len).collect::<Vec<_>>() {
                    Ok(())
                } else {
                    Err("not a permutation".into())
                }
            },
        );
    }
}
