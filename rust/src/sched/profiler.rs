//! Device benchmarking: measure per-device step time, derive scores.
//!
//! Paper §III-C "Offline Benchmarking": before the main loop, run a few
//! fwd/bwd passes of the target model with a small fixed batch on every
//! device; the fastest device scores 1.0 and device i scores
//! `t_fastest / t_i`. Scores feed [`super::allocation`].
//!
//! Two sources of timings:
//! * [`Profiler::profile_real`] — wall-clock timing of actual PJRT
//!   `grad_step` executions (plus the device throttle, so the imposed
//!   heterogeneity is observed exactly the way a real mixed cluster's
//!   would be);
//! * [`Profiler::profile_model`] — the calibrated [`SpeedModel`], used by
//!   virtual-time simulation and unit tests.

use std::time::Instant;

use crate::device::{DeviceSpec, SpeedModel};
use crate::runtime::{BatchData, ModelPrograms};
use crate::Result;

/// Benchmark configuration.
#[derive(Debug, Clone, Copy)]
pub struct Profiler {
    /// Untimed warm-up iterations (compile + cache effects).
    pub warmup_iters: usize,
    /// Timed iterations; the median is used.
    pub timed_iters: usize,
    /// Per-device probe batch size (paper: "a small, fixed amount of
    /// data").
    pub probe_batch: usize,
}

impl Default for Profiler {
    fn default() -> Self {
        Self {
            warmup_iters: 2,
            timed_iters: 5,
            probe_batch: 16,
        }
    }
}

impl Profiler {
    /// Convert raw per-device times into paper scores
    /// (`fastest == 1.0`, slower < 1.0).
    pub fn scores_from_times(times: &[f64]) -> Vec<f64> {
        let best = times.iter().copied().fold(f64::INFINITY, f64::min);
        if !best.is_finite() || best <= 0.0 {
            return vec![1.0; times.len()];
        }
        times.iter().map(|t| best / t).collect()
    }

    /// Time one device's real `grad_step` (median of `timed_iters`),
    /// *including* the heterogeneity throttle applied by the caller via
    /// `throttle` (seconds of extra sleep per measured second).
    pub fn profile_real(
        &self,
        progs: &ModelPrograms,
        params: &[f32],
        batch: &BatchData,
        throttle_factor: f64,
    ) -> Result<f64> {
        for _ in 0..self.warmup_iters {
            progs.grad_step(params, batch)?;
        }
        let mut times = Vec::with_capacity(self.timed_iters);
        for _ in 0..self.timed_iters {
            let t0 = Instant::now();
            progs.grad_step(params, batch)?;
            let measured = t0.elapsed().as_secs_f64();
            let extra = measured * (throttle_factor - 1.0).max(0.0);
            if extra > 0.0 {
                std::thread::sleep(std::time::Duration::from_secs_f64(extra));
            }
            times.push(measured * throttle_factor.max(1.0));
        }
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Ok(times[times.len() / 2])
    }

    /// Modeled per-device probe times from the calibrated speed model.
    pub fn profile_model(&self, devices: &[DeviceSpec], model: &SpeedModel) -> Vec<f64> {
        devices
            .iter()
            .map(|d| model.step_time(d.dtype, self.probe_batch))
            .collect()
    }

    /// Modeled scores for a cluster (used by simnet and by real mode as
    /// the prior when `--no-profile` is set).
    pub fn model_scores(&self, devices: &[DeviceSpec], model: &SpeedModel) -> Vec<f64> {
        Self::scores_from_times(&self.profile_model(devices, model))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::{parse_cluster, DeviceType};

    #[test]
    fn scores_fastest_is_one() {
        let s = Profiler::scores_from_times(&[0.02, 0.017, 0.04]);
        assert!((s[1] - 1.0).abs() < 1e-12);
        assert!(s[0] < 1.0 && s[2] < s[0]);
    }

    #[test]
    fn scores_equal_times_all_one() {
        let s = Profiler::scores_from_times(&[0.5, 0.5]);
        assert_eq!(s, vec![1.0, 1.0]);
    }

    #[test]
    fn degenerate_times_fall_back() {
        assert_eq!(Profiler::scores_from_times(&[0.0, 0.0]), vec![1.0, 1.0]);
    }

    #[test]
    fn model_scores_match_paper_shape() {
        let p = Profiler {
            probe_batch: 128,
            ..Default::default()
        };
        let devices = parse_cluster("1G+1M").unwrap();
        let scores = p.model_scores(&devices, &SpeedModel::paper_default());
        // MLU fastest → 1.0; GPU ≈ 0.7.
        assert!((scores[1] - 1.0).abs() < 1e-12);
        assert!((0.6..0.8).contains(&scores[0]), "{scores:?}");
        assert_eq!(devices[0].dtype, DeviceType::GpuSim);
    }
}
