//! Pooled, reference-counted buffers — the allocation substrate of the
//! data plane.
//!
//! Every hop of the old payload path (writer queue, frame read, mailbox
//! push, ring chunk split, relay staging) allocated and memcpy'd a fresh
//! `Vec<u8>`. Here a payload lives in a [`Buf`]: `Arc`-backed storage
//! plus an offset/len window, so handing a message to a writer thread,
//! parking it in a mailbox slot, or slicing a chunk out of it is a
//! refcount bump — never a copy. Storage comes from a [`BufPool`]:
//! sharded (per-thread shard affinity, HetCCL/sharded-slab style) and
//! size-classed (powers of two), with hit/miss/alloc statistics so the
//! copy-count reduction is observable in reports.
//!
//! [`FloatPool`] is the same idea for the `Vec<f32>` staging buffers the
//! host relay and the DDP bucketizer churn through.

use std::ops::Deref;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, OnceLock, Weak};

use crate::comm::slab::{thread_shard, TaggedStack};

/// Shards per size class: spreads free-list traffic across stacks.
const SHARDS_PER_CLASS: usize = 8;
/// Free buffers kept per shard per class (bounds pooled memory).
const MAX_FREE_PER_SHARD: usize = 8;

/// Default streaming chunk granularity (overridable via
/// `KAITIAN_CHUNK_BYTES` or [`set_chunk_bytes`]): 256 KiB keeps several
/// chunks in flight for MiB-scale tensors without drowning small ops in
/// per-message overhead.
pub const DEFAULT_CHUNK_BYTES: usize = 256 << 10;

static CHUNK_BYTES: AtomicUsize = AtomicUsize::new(0);

/// Round a requested chunk size to the granularity the data plane
/// accepts: a multiple of 4 bytes, at least one f32.
fn round_chunk(bytes: usize) -> usize {
    (bytes.max(4) / 4) * 4
}

/// The data-plane chunk granularity in bytes (always a multiple of 4).
/// A malformed `KAITIAN_CHUNK_BYTES` falls back to the default with a
/// one-time stderr warning (never silently).
pub fn chunk_bytes() -> usize {
    let v = CHUNK_BYTES.load(Ordering::Relaxed);
    if v != 0 {
        return v;
    }
    let v = crate::util::env_or_warn("KAITIAN_CHUNK_BYTES", DEFAULT_CHUNK_BYTES);
    let v = round_chunk(v);
    CHUNK_BYTES.store(v, Ordering::Relaxed);
    v
}

/// Override the chunk granularity (benches/tests). Rounded down to a
/// multiple of 4, clamped to at least one f32. Must not change while
/// collectives are in flight (ranks must agree on chunk counts), so
/// callers in multi-test binaries serialize around it.
pub fn set_chunk_bytes(bytes: usize) {
    CHUNK_BYTES.store(round_chunk(bytes), Ordering::Relaxed);
}

/// Stable per-thread shard index (round-robin assignment on first use,
/// shared with the slab arenas so affinity lines up across structures).
fn shard_index() -> usize {
    thread_shard(SHARDS_PER_CLASS)
}

/// Counters exposed by both pools (fresh allocations vs. reuse).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct PoolStats {
    /// Bytes freshly allocated from the system (misses + oversize).
    pub alloc_bytes: u64,
    /// Takes served from the free lists.
    pub pool_hits: u64,
    /// Takes that had to allocate.
    pub pool_misses: u64,
    /// Buffers returned to the free lists.
    pub recycled: u64,
}

/// Sharded size-classed free lists over `Vec<T>` (the engine behind both
/// [`BufPool`] and [`FloatPool`]). Classes are powers of two between
/// `1 << min_shift` and `1 << max_shift` *elements*; larger requests
/// fall through to plain allocation.
struct PoolCore<T> {
    /// `classes * SHARDS_PER_CLASS` free lists — lock-free tagged
    /// Treiber stacks (ISSUE 6), each bounded at [`MAX_FREE_PER_SHARD`]
    /// by construction. Vectors keep their stale (initialized) contents
    /// so a take only writes the length delta — callers fully overwrite
    /// what they take.
    free: Vec<TaggedStack<Vec<T>>>,
    enabled: AtomicBool,
    min_shift: u32,
    max_shift: u32,
    elem_bytes: u64,
    alloc_bytes: AtomicU64,
    pool_hits: AtomicU64,
    pool_misses: AtomicU64,
    recycled: AtomicU64,
}

impl<T: Clone + Default> PoolCore<T> {
    fn new(min_shift: u32, max_shift: u32, elem_bytes: u64) -> Self {
        let classes = (max_shift - min_shift + 1) as usize;
        let free = (0..classes * SHARDS_PER_CLASS)
            .map(|_| TaggedStack::new(MAX_FREE_PER_SHARD))
            .collect();
        Self {
            free,
            enabled: AtomicBool::new(true),
            min_shift,
            max_shift,
            elem_bytes,
            alloc_bytes: AtomicU64::new(0),
            pool_hits: AtomicU64::new(0),
            pool_misses: AtomicU64::new(0),
            recycled: AtomicU64::new(0),
        }
    }

    /// Smallest class whose capacity fits `len` elements.
    fn class_for(&self, len: usize) -> Option<usize> {
        debug_assert!(len > 0);
        let bits = usize::BITS - (len - 1).leading_zeros();
        let shift = bits.max(self.min_shift);
        if shift > self.max_shift {
            None
        } else {
            Some((shift - self.min_shift) as usize)
        }
    }

    /// Largest class whose capacity is at most `cap` elements (for
    /// recycling foreign vectors without risking reallocation).
    /// Capacities beyond the largest class are rejected — parking a
    /// giant one-off buffer in the top class would retain its full
    /// capacity forever and break the pool's memory bound.
    fn class_for_cap(&self, cap: usize) -> Option<usize> {
        if cap < (1_usize << self.min_shift) || cap > (1_usize << self.max_shift) {
            return None;
        }
        let floor = (usize::BITS - 1 - cap.leading_zeros()).min(self.max_shift);
        Some((floor - self.min_shift) as usize)
    }

    fn class_len(&self, class: usize) -> usize {
        1_usize << (class as u32 + self.min_shift)
    }

    /// A vector of exactly `len` elements (default-initialized); `true`
    /// when it was served from a free list.
    fn take(&self, len: usize) -> (Vec<T>, bool) {
        if len == 0 {
            return (Vec::new(), true);
        }
        if self.enabled.load(Ordering::Relaxed) {
            if let Some(class) = self.class_for(len) {
                // Own shard first (fast path); on a miss, probe the
                // sibling shards before falling through to allocation —
                // producer/consumer thread splits (e.g. the TCP reader
                // allocates, the collective thread frees) would
                // otherwise never find their buffers again. Each probe
                // is one lock-free stack pop.
                let base = class * SHARDS_PER_CLASS;
                let start = shard_index();
                for i in 0..SHARDS_PER_CLASS {
                    let shard = &self.free[base + (start + i) % SHARDS_PER_CLASS];
                    if let Some(mut v) = shard.pop() {
                        self.pool_hits.fetch_add(1, Ordering::Relaxed);
                        v.resize(len, T::default());
                        return (v, true);
                    }
                }
            }
        }
        self.pool_misses.fetch_add(1, Ordering::Relaxed);
        let cap = match self.class_for(len) {
            Some(class) if self.enabled.load(Ordering::Relaxed) => self.class_len(class),
            _ => len,
        };
        self.alloc_bytes
            .fetch_add(cap as u64 * self.elem_bytes, Ordering::Relaxed);
        let mut v = Vec::with_capacity(cap);
        v.resize(len, T::default());
        (v, false)
    }

    /// Return a vector to the free lists (dropped when pooling is off,
    /// the capacity is outside the class range, or the shard is full).
    /// Contents are kept as-is — re-zeroing every recycled frame would
    /// put a full memset back on the hot path the pool exists to remove.
    fn put(&self, v: Vec<T>) {
        if !self.enabled.load(Ordering::Relaxed) {
            return;
        }
        let Some(class) = self.class_for_cap(v.capacity()) else {
            return;
        };
        let shard = &self.free[class * SHARDS_PER_CLASS + shard_index()];
        // The stack's fixed capacity *is* the MAX_FREE_PER_SHARD bound:
        // a push into a full shard hands the vector back and we drop it.
        if shard.push(v).is_ok() {
            self.recycled.fetch_add(1, Ordering::Relaxed);
        }
    }

    fn set_enabled(&self, on: bool) {
        self.enabled.store(on, Ordering::Relaxed);
        if !on {
            for shard in &self.free {
                while shard.pop().is_some() {}
            }
        }
    }

    fn stats(&self) -> PoolStats {
        PoolStats {
            alloc_bytes: self.alloc_bytes.load(Ordering::Relaxed),
            pool_hits: self.pool_hits.load(Ordering::Relaxed),
            pool_misses: self.pool_misses.load(Ordering::Relaxed),
            recycled: self.recycled.load(Ordering::Relaxed),
        }
    }

    fn reset_stats(&self) {
        self.alloc_bytes.store(0, Ordering::Relaxed);
        self.pool_hits.store(0, Ordering::Relaxed);
        self.pool_misses.store(0, Ordering::Relaxed);
        self.recycled.store(0, Ordering::Relaxed);
    }
}

// ---------------------------------------------------------------------
// byte buffers
// ---------------------------------------------------------------------

/// Sharded size-classed pool of byte buffers (256 B .. 16 MiB classes).
pub struct BufPool {
    core: Arc<PoolCore<u8>>,
}

impl Default for BufPool {
    fn default() -> Self {
        Self::new()
    }
}

impl BufPool {
    pub fn new() -> Self {
        Self {
            core: Arc::new(PoolCore::new(8, 24, 1)),
        }
    }

    /// The process-wide pool the transports and collectives share.
    pub fn global() -> &'static BufPool {
        static POOL: OnceLock<BufPool> = OnceLock::new();
        POOL.get_or_init(BufPool::new)
    }

    /// A writable buffer of exactly `len` bytes. Contents are
    /// unspecified (recycled buffers keep stale data) — callers fully
    /// overwrite before freezing.
    pub fn take(&self, len: usize) -> BufMut {
        self.take_tracked(len).0
    }

    /// Like [`BufPool::take`], also reporting whether the free list
    /// served it (`true`) or it was freshly allocated — the per-op
    /// `CommStats` accounting hook.
    pub fn take_tracked(&self, len: usize) -> (BufMut, bool) {
        let (data, hit) = self.core.take(len);
        (
            BufMut {
                data,
                pool: Arc::downgrade(&self.core),
            },
            hit,
        )
    }

    /// A raw pooled byte vector of exactly `len` (contents unspecified —
    /// callers overwrite fully); `true` when served from a free list.
    /// The dtype-generic collectives assemble their outputs in these;
    /// return them with [`BufPool::put_vec`].
    pub fn take_vec(&self, len: usize) -> (Vec<u8>, bool) {
        self.core.take(len)
    }

    /// Return a vector from [`BufPool::take_vec`] for reuse.
    pub fn put_vec(&self, v: Vec<u8>) {
        self.core.put(v);
    }

    /// Copy `bytes` into a pooled buffer and freeze it.
    pub fn buf_from(&self, bytes: &[u8]) -> Buf {
        let mut b = self.take(bytes.len());
        b.as_mut_slice().copy_from_slice(bytes);
        b.freeze()
    }

    /// Turn pooling on/off (off = every take is a fresh allocation and
    /// every release a plain free — the pre-refactor copy path, kept for
    /// the dataplane bench baseline).
    pub fn set_enabled(&self, on: bool) {
        self.core.set_enabled(on);
    }

    pub fn stats(&self) -> PoolStats {
        self.core.stats()
    }

    pub fn reset_stats(&self) {
        self.core.reset_stats();
    }
}

/// Backing storage of a frozen [`Buf`]; returns itself to its pool when
/// the last reference drops.
struct Storage {
    data: Vec<u8>,
    pool: Weak<PoolCore<u8>>,
}

impl Drop for Storage {
    fn drop(&mut self) {
        if let Some(pool) = self.pool.upgrade() {
            pool.put(std::mem::take(&mut self.data));
        }
    }
}

/// A uniquely-owned writable buffer; [`BufMut::freeze`] turns it into a
/// shareable [`Buf`].
pub struct BufMut {
    data: Vec<u8>,
    pool: Weak<PoolCore<u8>>,
}

impl BufMut {
    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn as_mut_slice(&mut self) -> &mut [u8] {
        &mut self.data
    }

    /// Freeze into an immutable, cheaply-cloneable [`Buf`].
    pub fn freeze(self) -> Buf {
        let len = self.data.len();
        Buf {
            storage: Arc::new(Storage {
                data: self.data,
                pool: self.pool,
            }),
            off: 0,
            len,
        }
    }
}

/// An immutable, reference-counted view into pooled storage. Cloning or
/// slicing is a refcount bump; the storage is recycled when the last
/// view drops.
#[derive(Clone)]
pub struct Buf {
    storage: Arc<Storage>,
    off: usize,
    len: usize,
}

impl Buf {
    /// An empty buffer (no storage behind it worth pooling).
    pub fn empty() -> Buf {
        Buf::from_vec(Vec::new())
    }

    /// Wrap an existing vector (unpooled storage; freed normally).
    pub fn from_vec(data: Vec<u8>) -> Buf {
        let len = data.len();
        Buf {
            storage: Arc::new(Storage {
                data,
                pool: Weak::new(),
            }),
            off: 0,
            len,
        }
    }

    /// Copy `bytes` into the global pool.
    pub fn copy_from_slice(bytes: &[u8]) -> Buf {
        BufPool::global().buf_from(bytes)
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// A zero-copy sub-view (`start..end` within this view).
    pub fn slice(&self, start: usize, end: usize) -> Buf {
        assert!(start <= end && end <= self.len, "slice out of bounds");
        Buf {
            storage: self.storage.clone(),
            off: self.off + start,
            len: end - start,
        }
    }

    pub fn as_slice(&self) -> &[u8] {
        &self.storage.data[self.off..self.off + self.len]
    }
}

impl Deref for Buf {
    type Target = [u8];

    fn deref(&self) -> &[u8] {
        self.as_slice()
    }
}

impl AsRef<[u8]> for Buf {
    fn as_ref(&self) -> &[u8] {
        self.as_slice()
    }
}

impl std::fmt::Debug for Buf {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Buf")
            .field("off", &self.off)
            .field("len", &self.len)
            .finish()
    }
}

impl PartialEq for Buf {
    fn eq(&self, other: &Self) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl PartialEq<[u8]> for Buf {
    fn eq(&self, other: &[u8]) -> bool {
        self.as_slice() == other
    }
}

impl PartialEq<Vec<u8>> for Buf {
    fn eq(&self, other: &Vec<u8>) -> bool {
        self.as_slice() == other.as_slice()
    }
}

// ---------------------------------------------------------------------
// f32 staging buffers
// ---------------------------------------------------------------------

/// Pool of `Vec<f32>` staging buffers (64 elem .. 4 Mi elem classes —
/// the same 256 B .. 16 MiB byte range as [`BufPool`]). Used by the host
/// relay for D2H/H2D staging and by DDP for bucket hand-off buffers.
pub struct FloatPool {
    core: PoolCore<f32>,
}

impl Default for FloatPool {
    fn default() -> Self {
        Self::new()
    }
}

impl FloatPool {
    pub fn new() -> Self {
        Self {
            core: PoolCore::new(6, 22, 4),
        }
    }

    pub fn global() -> &'static FloatPool {
        static POOL: OnceLock<FloatPool> = OnceLock::new();
        POOL.get_or_init(FloatPool::new)
    }

    /// A vector of exactly `len` elements; contents unspecified
    /// (recycled vectors keep stale data) — callers overwrite it fully.
    pub fn take(&self, len: usize) -> Vec<f32> {
        self.take_tracked(len).0
    }

    /// Like [`FloatPool::take`], also reporting free-list reuse.
    pub fn take_tracked(&self, len: usize) -> (Vec<f32>, bool) {
        self.core.take(len)
    }

    /// Return a vector for reuse.
    pub fn put(&self, v: Vec<f32>) {
        self.core.put(v);
    }

    pub fn set_enabled(&self, on: bool) {
        self.core.set_enabled(on);
    }

    pub fn stats(&self) -> PoolStats {
        self.core.stats()
    }

    pub fn reset_stats(&self) {
        self.core.reset_stats();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slices_are_zero_copy_views() {
        let pool = BufPool::new();
        let mut b = pool.take(8);
        b.as_mut_slice().copy_from_slice(&[0, 1, 2, 3, 4, 5, 6, 7]);
        let buf = b.freeze();
        let mid = buf.slice(2, 6);
        assert_eq!(mid.as_slice(), &[2, 3, 4, 5]);
        let tail = mid.slice(2, 4);
        assert_eq!(tail.as_slice(), &[4, 5]);
        assert_eq!(buf.len(), 8);
    }

    #[test]
    #[should_panic(expected = "out of bounds")]
    fn slice_past_end_panics() {
        let buf = Buf::from_vec(vec![1, 2, 3]);
        let _ = buf.slice(1, 4);
    }

    #[test]
    fn pool_recycles_storage() {
        let pool = BufPool::new();
        let (b, hit) = pool.take_tracked(1000);
        assert!(!hit, "first take must miss");
        drop(b.freeze()); // last ref -> recycled
        let (b2, hit2) = pool.take_tracked(900);
        assert!(hit2, "same class take must hit after recycle");
        assert_eq!(b2.len(), 900);
        let st = pool.stats();
        assert_eq!(st.pool_hits, 1);
        assert_eq!(st.pool_misses, 1);
        assert_eq!(st.recycled, 1);
        assert!(st.alloc_bytes >= 1000);
    }

    #[test]
    fn storage_outlives_pool_clones() {
        // A slice kept alive across other drops still reads valid data,
        // and recycling happens only once (on the last drop).
        let pool = BufPool::new();
        let mut b = pool.take(16);
        b.as_mut_slice()[0] = 42;
        let buf = b.freeze();
        let view = buf.slice(0, 1);
        drop(buf);
        assert_eq!(view.as_slice(), &[42]);
        assert_eq!(pool.stats().recycled, 0, "view still holds storage");
        drop(view);
        assert_eq!(pool.stats().recycled, 1);
    }

    #[test]
    fn disabled_pool_always_misses() {
        let pool = BufPool::new();
        pool.set_enabled(false);
        drop(pool.take(512).freeze());
        let (_, hit) = pool.take_tracked(512);
        assert!(!hit);
        assert_eq!(pool.stats().pool_hits, 0);
        assert_eq!(pool.stats().recycled, 0);
    }

    #[test]
    fn zero_len_take_is_free() {
        let pool = BufPool::new();
        let (b, hit) = pool.take_tracked(0);
        assert!(hit);
        assert!(b.is_empty());
        assert_eq!(pool.stats().alloc_bytes, 0);
        assert!(Buf::empty().is_empty());
    }

    #[test]
    fn oversize_takes_fall_through() {
        let pool = BufPool::new();
        let (b, hit) = pool.take_tracked((16 << 20) + 1);
        assert!(!hit);
        drop(b.freeze());
        // Too large for any class: not recycled.
        assert_eq!(pool.stats().recycled, 0);
    }

    #[test]
    fn float_pool_recycles_by_capacity() {
        let pool = FloatPool::new();
        let (v, hit) = pool.take_tracked(100);
        assert!(!hit);
        assert_eq!(v.len(), 100);
        assert!(v.iter().all(|&x| x == 0.0));
        pool.put(v);
        let (v2, hit2) = pool.take_tracked(128);
        assert!(hit2, "128 elems fits the same 128-elem class");
        assert_eq!(v2.len(), 128);
        // A foreign vector with tiny capacity is dropped, not pooled.
        pool.put(Vec::with_capacity(3));
        assert_eq!(pool.stats().recycled, 1);
    }

    #[test]
    fn chunk_rounding_is_f32_aligned() {
        // The global setter is exercised by integration tests (which
        // serialize); the rounding rule is pure and testable here.
        assert_eq!(round_chunk(1000), 1000);
        assert_eq!(round_chunk(1001), 1000);
        assert_eq!(round_chunk(1), 4);
        assert_eq!(round_chunk(0), 4);
        assert_eq!(round_chunk(DEFAULT_CHUNK_BYTES), DEFAULT_CHUNK_BYTES);
        assert_eq!(chunk_bytes() % 4, 0);
    }
}
