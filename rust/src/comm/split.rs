//! Disjoint mutable chunk views of one `Vec<f32>`.
//!
//! The KaiTian 3-stage pipeline streams a bucket through its stage
//! threads chunk by chunk: chunk *k* can be crossing the host relay
//! while chunk *k+1* is still inside its vendor reduce. Each stage needs
//! `&mut [f32]` access to its chunk from a different thread, so the
//! bucket is split into non-overlapping [`ChunkMut`] views (the
//! `split_at_mut` pattern, made `'static` by leaking the vector behind
//! an `Arc` owner) and reassembled — same allocation, no copy — once
//! every chunk has been dropped.

use std::sync::Arc;

/// Owner of the leaked vector; frees it if the group is never reclaimed
/// (e.g. a pipeline error path dropped everything early).
struct VecOwner {
    ptr: *mut f32,
    len: usize,
    cap: usize,
}

// SAFETY: the owner only carries the raw parts; all access to the
// elements goes through the disjoint `ChunkMut` views.
unsafe impl Send for VecOwner {}
unsafe impl Sync for VecOwner {}

impl Drop for VecOwner {
    fn drop(&mut self) {
        // SAFETY: `split_chunks` forgot the original Vec, so these raw
        // parts are exclusively ours; every `ChunkMut` holds an `Arc` to
        // this owner, so none can be alive once Drop runs.
        unsafe {
            drop(Vec::from_raw_parts(self.ptr, self.len, self.cap));
        }
    }
}

/// Handle used to reassemble the vector after the chunks are done.
pub struct ChunkGroup {
    owner: Arc<VecOwner>,
}

impl std::fmt::Debug for ChunkGroup {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ChunkGroup")
            .field("len", &self.owner.len)
            .field("live_chunks", &(Arc::strong_count(&self.owner) - 1))
            .finish()
    }
}

impl ChunkGroup {
    /// Reassemble the original `Vec<f32>` (same allocation, no copy).
    /// Fails — handing the group back — while any [`ChunkMut`] is alive.
    pub fn try_reclaim(self) -> Result<Vec<f32>, ChunkGroup> {
        match Arc::try_unwrap(self.owner) {
            Ok(owner) => {
                // SAFETY: unique ownership proven by try_unwrap; forget
                // the owner so its Drop cannot free the parts twice.
                let v = unsafe { Vec::from_raw_parts(owner.ptr, owner.len, owner.cap) };
                std::mem::forget(owner);
                Ok(v)
            }
            Err(owner) => Err(ChunkGroup { owner }),
        }
    }
}

/// A sendable `&mut [f32]` view of one chunk of the split vector.
pub struct ChunkMut {
    ptr: *mut f32,
    len: usize,
    _owner: Arc<VecOwner>,
}

// SAFETY: chunks are constructed over non-overlapping ranges, so at most
// one thread can touch any element through a ChunkMut; the Arc keeps the
// backing allocation alive for as long as the view exists.
unsafe impl Send for ChunkMut {}

impl ChunkMut {
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn as_mut_slice(&mut self) -> &mut [f32] {
        // SAFETY: disjointness + liveness per the struct invariant; `&mut
        // self` prevents aliasing through this view.
        unsafe { std::slice::from_raw_parts_mut(self.ptr, self.len) }
    }
}

/// Split `buf` into `<= chunk_elems`-sized disjoint mutable views.
/// Returns the reassembly handle plus the chunks in offset order
/// (empty chunk list for an empty `buf`).
pub fn split_chunks(buf: Vec<f32>, chunk_elems: usize) -> (ChunkGroup, Vec<ChunkMut>) {
    assert!(chunk_elems > 0, "chunk_elems must be positive");
    let mut buf = std::mem::ManuallyDrop::new(buf);
    let (ptr, len, cap) = (buf.as_mut_ptr(), buf.len(), buf.capacity());
    let owner = Arc::new(VecOwner { ptr, len, cap });
    let mut chunks = Vec::with_capacity(len.div_ceil(chunk_elems.max(1)));
    let mut start = 0;
    while start < len {
        let n = chunk_elems.min(len - start);
        chunks.push(ChunkMut {
            // SAFETY: start + n <= len, so the view stays in bounds.
            ptr: unsafe { ptr.add(start) },
            len: n,
            _owner: owner.clone(),
        });
        start += n;
    }
    (ChunkGroup { owner }, chunks)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunks_partition_and_reclaim() {
        let buf: Vec<f32> = (0..10).map(|i| i as f32).collect();
        let (group, mut chunks) = split_chunks(buf, 4);
        assert_eq!(
            chunks.iter().map(|c| c.len()).collect::<Vec<_>>(),
            vec![4, 4, 2]
        );
        for c in &mut chunks {
            for x in c.as_mut_slice() {
                *x += 100.0;
            }
        }
        drop(chunks);
        let back = group.try_reclaim().expect("all chunks dropped");
        let expect: Vec<f32> = (0..10).map(|i| i as f32 + 100.0).collect();
        assert_eq!(back, expect);
    }

    #[test]
    fn reclaim_refused_while_chunk_alive() {
        let (group, mut chunks) = split_chunks(vec![1.0, 2.0, 3.0], 2);
        let last = chunks.pop().unwrap();
        drop(chunks);
        let group = group.try_reclaim().expect_err("one chunk still alive");
        drop(last);
        assert_eq!(group.try_reclaim().unwrap(), vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn concurrent_chunk_writes_from_threads() {
        let buf = vec![0.0_f32; 1000];
        let (group, chunks) = split_chunks(buf, 128);
        std::thread::scope(|s| {
            for (i, mut c) in chunks.into_iter().enumerate() {
                s.spawn(move || {
                    for x in c.as_mut_slice() {
                        *x = i as f32;
                    }
                });
            }
        });
        let back = group.try_reclaim().unwrap();
        for (j, &x) in back.iter().enumerate() {
            assert_eq!(x, (j / 128) as f32, "elem {j}");
        }
    }

    #[test]
    fn empty_vec_reclaims() {
        let (group, chunks) = split_chunks(Vec::new(), 8);
        assert!(chunks.is_empty());
        assert!(group.try_reclaim().unwrap().is_empty());
    }
}
