//! Lock-free slab primitives for the data-plane hot paths.
//!
//! Three building blocks, shared by the mailbox, the buffer pools and
//! the comm-thread completion queue (ROADMAP open item 2 — the
//! `sharded-slab` idiom):
//!
//! * [`Arena`] — a grow-only segmented slot store with sharded atomic
//!   free lists (per-thread shard affinity via [`thread_shard`]) and a
//!   per-slot **generation counter**. Slot storage is never freed while
//!   the arena lives, so a stale index dereference reads *old* data,
//!   never unmapped memory; the generation tag in every published
//!   reference ([`pack`]) makes stale references detectable and defeats
//!   ABA on every compare-and-swap.
//! * [`Queue`] — a Michael–Scott MPMC FIFO whose nodes live in an
//!   `Arena<Node<V>>`. Retired nodes go back to the arena free lists,
//!   so a long-lived queue allocates only up to its high-water mark.
//! * [`TaggedStack`] — a fixed-capacity Treiber stack with versioned
//!   heads (push/pop are single CAS loops, no locks), used for the
//!   `BufPool`/`FloatPool` per-shard free lists.
//!
//! Memory-reclamation model: nothing here uses hazard pointers or
//! epochs. Instead, slots are only *recycled* (never deallocated), and
//! every protocol is written so that a value cell is read or written
//! only while the reader/writer holds exclusive ownership of the slot —
//! ownership is handed over through tagged CAS operations that fail if
//! the slot was recycled underneath (generation mismatch).

use std::cell::{Cell, UnsafeCell};
use std::sync::atomic::{AtomicPtr, AtomicU32, AtomicU64, Ordering};

/// Sentinel index meaning "no slot" in packed references and free lists.
pub const NIL: u32 = u32::MAX;

/// Pack a (generation, index) pair into one 64-bit tagged reference.
#[inline]
pub fn pack(gen: u32, idx: u32) -> u64 {
    ((gen as u64) << 32) | idx as u64
}

/// The generation tag of a packed reference.
#[inline]
pub fn ref_gen(r: u64) -> u32 {
    (r >> 32) as u32
}

/// The slot index of a packed reference ([`NIL`] when absent).
#[inline]
pub fn ref_idx(r: u64) -> u32 {
    r as u32
}

/// Stable per-thread shard index in `0..shards` (round-robin assignment
/// on first use). All slab consumers share one thread-local counter, so
/// a thread lands on the same shard of every sharded structure — the
/// "shard affinity" that keeps free-list traffic thread-local.
pub fn thread_shard(shards: usize) -> usize {
    static NEXT: AtomicU32 = AtomicU32::new(0);
    thread_local! {
        static SEED: Cell<u32> = const { Cell::new(u32::MAX) };
    }
    SEED.with(|s| {
        let mut v = s.get();
        if v == u32::MAX {
            v = NEXT.fetch_add(1, Ordering::Relaxed);
            s.set(v);
        }
        v as usize % shards.max(1)
    })
}

// ---------------------------------------------------------------------
// generation-tagged arena
// ---------------------------------------------------------------------

/// log2 of the first segment's slot count (segment `i` holds
/// `64 << i` slots, so capacity doubles per segment like `Vec` growth
/// but without ever moving existing slots).
const SEG0_BITS: u32 = 6;
/// Number of doubling segments: total capacity ≈ 67 M slots.
const MAX_SEGS: usize = 20;
/// Free-list shards (matches the pool shard count so a thread's
/// affinity index is meaningful for both).
const FREE_SHARDS: usize = 8;

/// Segment index holding slot `idx`.
#[inline]
fn seg_of(idx: u32) -> usize {
    let bucket = (idx >> SEG0_BITS) + 1;
    (u32::BITS - 1 - bucket.leading_zeros()) as usize
}

/// First slot index of segment `s`.
#[inline]
fn seg_base(s: usize) -> u32 {
    (((1_u64 << s) - 1) << SEG0_BITS) as u32
}

/// Slot count of segment `s`.
#[inline]
fn seg_len(s: usize) -> usize {
    64_usize << s
}

/// Cache-line padded atomic free-list head (one per shard) so shards do
/// not false-share.
#[repr(align(64))]
struct PaddedHead(AtomicU64);

/// One slot of an [`Arena`]: the caller's item plus the generation
/// counter and the free-list link.
pub struct ArenaSlot<T> {
    gen: AtomicU32,
    free_next: AtomicU32,
    /// The caller's payload. Reinitialized by the caller after every
    /// [`Arena::alloc`] (slots are recycled, not zeroed).
    pub item: T,
}

impl<T: Default> Default for ArenaSlot<T> {
    fn default() -> Self {
        Self {
            gen: AtomicU32::new(0),
            free_next: AtomicU32::new(NIL),
            item: T::default(),
        }
    }
}

impl<T> ArenaSlot<T> {
    /// Current generation of this slot. A tagged reference is valid only
    /// while its [`ref_gen`] equals this value; [`Arena::retire`] bumps
    /// it, invalidating every outstanding reference at once.
    #[inline]
    pub fn generation(&self) -> u32 {
        self.gen.load(Ordering::Acquire)
    }
}

/// Grow-only segmented slot store with sharded lock-free free lists and
/// per-slot generation counters.
///
/// `alloc` pops a recycled slot from the caller's affine free-list shard
/// (probing siblings on a miss) or bump-allocates a fresh slot; `retire`
/// bumps the slot's generation and pushes it back. Slot storage is
/// stable for the arena's lifetime — an index never dangles, and the
/// generation tag tells the live incarnation from a stale one.
pub struct Arena<T> {
    segs: [AtomicPtr<ArenaSlot<T>>; MAX_SEGS],
    fresh: AtomicU32,
    free: [PaddedHead; FREE_SHARDS],
}

// SAFETY: the raw segment pointers are owned by the arena (allocated in
// `ensure_segment`, freed only in `Drop`); shared access to the slots
// goes through `&ArenaSlot<T>`, so the usual bounds apply.
unsafe impl<T: Send + Sync> Send for Arena<T> {}
unsafe impl<T: Send + Sync> Sync for Arena<T> {}

impl<T: Default> Default for Arena<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T: Default> Arena<T> {
    /// An empty arena (no segments allocated yet).
    pub fn new() -> Self {
        Self {
            segs: std::array::from_fn(|_| AtomicPtr::new(std::ptr::null_mut())),
            fresh: AtomicU32::new(0),
            free: std::array::from_fn(|_| PaddedHead(AtomicU64::new(pack(0, NIL)))),
        }
    }

    /// Total slot capacity across all segments.
    fn capacity() -> usize {
        ((1_usize << MAX_SEGS) - 1) << SEG0_BITS
    }

    /// Allocate a slot index: own free shard first, then siblings, then
    /// a fresh bump-allocated slot. The returned slot's `item` holds
    /// whatever its previous incarnation left — callers reinitialize.
    pub fn alloc(&self) -> u32 {
        let start = thread_shard(FREE_SHARDS);
        for i in 0..FREE_SHARDS {
            if let Some(idx) = self.free_pop((start + i) % FREE_SHARDS) {
                return idx;
            }
        }
        let idx = self.fresh.fetch_add(1, Ordering::Relaxed);
        assert!((idx as usize) < Self::capacity(), "slab arena exhausted");
        self.ensure_segment(seg_of(idx));
        idx
    }

    /// Recycle a slot: bump its generation (invalidating every tagged
    /// reference to the old incarnation) and push it on the caller's
    /// affine free-list shard.
    ///
    /// The caller must hold exclusive ownership of the slot (it came
    /// from `alloc` and no other thread can still win a tagged CAS that
    /// hands the old incarnation over).
    pub fn retire(&self, idx: u32) {
        let slot = self.slot(idx);
        slot.gen.fetch_add(1, Ordering::Release);
        let head = &self.free[thread_shard(FREE_SHARDS)].0;
        let mut h = head.load(Ordering::Relaxed);
        loop {
            slot.free_next.store(ref_idx(h), Ordering::Relaxed);
            let next = pack(ref_gen(h).wrapping_add(1), idx);
            match head.compare_exchange_weak(h, next, Ordering::AcqRel, Ordering::Acquire) {
                Ok(_) => return,
                Err(cur) => h = cur,
            }
        }
    }

    /// The slot at `idx`. Panics (debug) if the segment was never
    /// allocated — indices must come from [`Arena::alloc`].
    #[inline]
    pub fn slot(&self, idx: u32) -> &ArenaSlot<T> {
        let s = seg_of(idx);
        let ptr = self.segs[s].load(Ordering::Acquire);
        debug_assert!(!ptr.is_null(), "slot index {idx} outside allocated segments");
        // SAFETY: segments are allocated with `seg_len(s)` slots before
        // any index inside them is handed out, and are freed only when
        // the arena drops (which borrows &mut self, excluding readers).
        unsafe { &*ptr.add((idx - seg_base(s)) as usize) }
    }

    /// Pop a recycled index off free shard `shard` (versioned-head
    /// Treiber pop; the version tag defeats ABA on the head).
    fn free_pop(&self, shard: usize) -> Option<u32> {
        let head = &self.free[shard].0;
        let mut h = head.load(Ordering::Acquire);
        loop {
            let idx = ref_idx(h);
            if idx == NIL {
                return None;
            }
            let next = self.slot(idx).free_next.load(Ordering::Acquire);
            let repl = pack(ref_gen(h).wrapping_add(1), next);
            match head.compare_exchange_weak(h, repl, Ordering::AcqRel, Ordering::Acquire) {
                Ok(_) => return Some(idx),
                Err(cur) => h = cur,
            }
        }
    }

    /// Allocate segment `s` if it does not exist yet (racing allocators
    /// may both build it; the CAS loser frees its copy).
    fn ensure_segment(&self, s: usize) {
        if !self.segs[s].load(Ordering::Acquire).is_null() {
            return;
        }
        let len = seg_len(s);
        let boxed: Box<[ArenaSlot<T>]> = (0..len).map(|_| ArenaSlot::default()).collect();
        let ptr = Box::into_raw(boxed) as *mut ArenaSlot<T>;
        if self
            .segs[s]
            .compare_exchange(
                std::ptr::null_mut(),
                ptr,
                Ordering::AcqRel,
                Ordering::Acquire,
            )
            .is_err()
        {
            // SAFETY: we just leaked this exact allocation via
            // `Box::into_raw` and nobody else has seen it.
            unsafe {
                drop(Box::from_raw(std::ptr::slice_from_raw_parts_mut(ptr, len)));
            }
        }
    }
}

impl<T> Drop for Arena<T> {
    fn drop(&mut self) {
        for s in 0..MAX_SEGS {
            let ptr = *self.segs[s].get_mut();
            if !ptr.is_null() {
                // SAFETY: the pointer came from `Box::into_raw` of a
                // `Box<[ArenaSlot<T>]>` with exactly `seg_len(s)` slots.
                unsafe {
                    drop(Box::from_raw(std::ptr::slice_from_raw_parts_mut(
                        ptr,
                        seg_len(s),
                    )));
                }
            }
        }
    }
}

// ---------------------------------------------------------------------
// MPMC queue over arena nodes
// ---------------------------------------------------------------------

/// One queue node: the intrusive link plus the value cell and the
/// two-claim retirement counter. Lives inside an `Arena<Node<V>>`.
pub struct Node<V> {
    /// `pack(target_gen, target_idx)` when linked to a successor, or
    /// `pack(own_gen, NIL)` while this node is the tail — carrying the
    /// owner's generation in the NIL marker makes a stale enqueuer's
    /// link CAS fail instead of splicing into a recycled node's queue.
    next: AtomicU64,
    /// Retirement claims: a node is recycled after both the popper that
    /// took its value (made it the dummy) and the popper that advanced
    /// the head past it have released it. Initial dummies start with the
    /// taker's claim pre-counted (they carry no value).
    claims: AtomicU32,
    value: UnsafeCell<Option<V>>,
}

impl<V> Default for Node<V> {
    fn default() -> Self {
        Self {
            next: AtomicU64::new(pack(0, NIL)),
            claims: AtomicU32::new(0),
            value: UnsafeCell::new(None),
        }
    }
}

// SAFETY: the value cell is accessed only under the queue's exclusive
// hand-over protocol (see `Queue::push`/`Queue::pop`), which transfers
// the value between threads — hence `V: Send` suffices.
unsafe impl<V: Send> Send for Node<V> {}
unsafe impl<V: Send> Sync for Node<V> {}

/// Michael–Scott MPMC FIFO over [`Arena`] nodes: lock-free push and
/// pop, tagged head/tail (no ABA), nodes recycled through the arena.
///
/// The queue itself is just two words, so it embeds cheaply in per-flow
/// slots; many queues can share one node arena.
pub struct Queue {
    head: AtomicU64,
    tail: AtomicU64,
}

impl Default for Queue {
    /// An uninitialized queue (head/tail are [`NIL`]); call
    /// [`Queue::init`] before pushing or popping.
    fn default() -> Self {
        Self {
            head: AtomicU64::new(pack(0, NIL)),
            tail: AtomicU64::new(pack(0, NIL)),
        }
    }
}

impl Queue {
    /// Initialize (or re-initialize after [`Queue::teardown`]) with a
    /// fresh dummy node. Callers must have exclusive access.
    pub fn init<V: Send>(&self, arena: &Arena<Node<V>>) {
        let idx = arena.alloc();
        let slot = arena.slot(idx);
        let gen = slot.generation();
        slot.item.next.store(pack(gen, NIL), Ordering::Relaxed);
        // Dummies carry no value: pre-count the taker's claim.
        slot.item.claims.store(1, Ordering::Relaxed);
        self.head.store(pack(gen, idx), Ordering::Relaxed);
        self.tail.store(pack(gen, idx), Ordering::Release);
    }

    /// Enqueue `value` (lock-free; two CAS operations uncontended).
    pub fn push<V: Send>(&self, arena: &Arena<Node<V>>, value: V) {
        let nidx = arena.alloc();
        let nslot = arena.slot(nidx);
        let ngen = nslot.generation();
        nslot.item.claims.store(0, Ordering::Relaxed);
        // SAFETY: `alloc` grants exclusive ownership of the node until
        // the link CAS below publishes it.
        unsafe { *nslot.item.value.get() = Some(value) };
        nslot.item.next.store(pack(ngen, NIL), Ordering::Release);
        let nref = pack(ngen, nidx);
        loop {
            let t = self.tail.load(Ordering::Acquire);
            let tidx = ref_idx(t);
            let tslot = arena.slot(tidx);
            let tnext = tslot.item.next.load(Ordering::Acquire);
            if self.tail.load(Ordering::Acquire) != t {
                continue; // tail moved (or node recycled) under us
            }
            if ref_idx(tnext) == NIL {
                if ref_gen(tnext) != ref_gen(t) {
                    continue; // stale incarnation of the tail node
                }
                // The expected value carries the tail node's generation,
                // so this CAS fails if the node was recycled.
                if tslot
                    .item
                    .next
                    .compare_exchange(tnext, nref, Ordering::AcqRel, Ordering::Acquire)
                    .is_ok()
                {
                    let _ = self
                        .tail
                        .compare_exchange(t, nref, Ordering::AcqRel, Ordering::Relaxed);
                    return;
                }
            } else {
                // Help a lagging pusher swing the tail forward.
                let _ = self
                    .tail
                    .compare_exchange(t, tnext, Ordering::AcqRel, Ordering::Relaxed);
            }
        }
    }

    /// Dequeue the oldest value, or `None` when empty (lock-free).
    pub fn pop<V: Send>(&self, arena: &Arena<Node<V>>) -> Option<V> {
        loop {
            let h = self.head.load(Ordering::Acquire);
            let t = self.tail.load(Ordering::Acquire);
            let hidx = ref_idx(h);
            if hidx == NIL {
                return None; // never initialized
            }
            let next = arena.slot(hidx).item.next.load(Ordering::Acquire);
            if self.head.load(Ordering::Acquire) != h {
                continue; // head moved (or dummy recycled) under us
            }
            if ref_idx(next) == NIL {
                return None;
            }
            if h == t {
                // Tail lags behind a linked node: help it forward so the
                // head never overtakes the tail.
                let _ = self
                    .tail
                    .compare_exchange(t, next, Ordering::AcqRel, Ordering::Relaxed);
            }
            if self
                .head
                .compare_exchange(h, next, Ordering::AcqRel, Ordering::Acquire)
                .is_ok()
            {
                let nidx = ref_idx(next);
                // SAFETY: winning the head CAS made `next` the dummy and
                // hands us exclusive ownership of its value; the node
                // cannot be recycled before both claims are released,
                // and ours is still outstanding.
                let v = unsafe { (*arena.slot(nidx).item.value.get()).take() };
                debug_assert!(v.is_some(), "queue node lost its value");
                Self::release(arena, nidx); // taker's claim on the new dummy
                Self::release(arena, hidx); // passer's claim on the old dummy
                return v;
            }
        }
    }

    /// Release one retirement claim; the second release recycles the
    /// node into the arena.
    fn release<V: Send>(arena: &Arena<Node<V>>, idx: u32) {
        if arena.slot(idx).item.claims.fetch_add(1, Ordering::AcqRel) == 1 {
            arena.retire(idx);
        }
    }

    /// Drain remaining values (dropping them) and retire every node
    /// including the dummy, returning the queue to its uninitialized
    /// state. Callers must have exclusive access (no concurrent
    /// push/pop) — the mailbox guarantees this via its pin protocol.
    pub fn teardown<V: Send>(&self, arena: &Arena<Node<V>>) {
        while self.pop(arena).is_some() {}
        let h = self.head.load(Ordering::Acquire);
        let hidx = ref_idx(h);
        if hidx != NIL {
            Self::release(arena, hidx); // final dummy: value already taken
            self.head.store(pack(0, NIL), Ordering::Relaxed);
            self.tail.store(pack(0, NIL), Ordering::Relaxed);
        }
    }
}

// ---------------------------------------------------------------------
// fixed-capacity tagged Treiber stack
// ---------------------------------------------------------------------

struct StackSlot<T> {
    next: AtomicU32,
    value: UnsafeCell<Option<T>>,
}

/// Fixed-capacity lock-free LIFO: a Treiber stack over preallocated
/// slots, with **versioned heads** (`version << 32 | index`) so head
/// CASes are ABA-safe without deferred reclamation. `push` fails (hands
/// the value back) when full — exactly the bounded-free-list semantics
/// the buffer pools need.
pub struct TaggedStack<T> {
    slots: Box<[StackSlot<T>]>,
    full: AtomicU64,
    vacant: AtomicU64,
}

// SAFETY: a slot's value cell is written only between popping the slot
// off the vacant list and pushing it on the full list (and read only in
// the mirror-image hand-over) — the tagged CAS transfers exclusive
// ownership, moving the value between threads.
unsafe impl<T: Send> Send for TaggedStack<T> {}
unsafe impl<T: Send> Sync for TaggedStack<T> {}

impl<T> TaggedStack<T> {
    /// A stack holding at most `capacity` values (capacity ≥ 1).
    pub fn new(capacity: usize) -> Self {
        assert!(capacity >= 1 && capacity < NIL as usize);
        let slots: Box<[StackSlot<T>]> = (0..capacity)
            .map(|i| StackSlot {
                next: AtomicU32::new(if i + 1 < capacity { i as u32 + 1 } else { NIL }),
                value: UnsafeCell::new(None),
            })
            .collect();
        Self {
            slots,
            full: AtomicU64::new(pack(0, NIL)),
            vacant: AtomicU64::new(pack(0, 0)),
        }
    }

    fn pop_from(&self, head: &AtomicU64) -> Option<u32> {
        let mut h = head.load(Ordering::Acquire);
        loop {
            let idx = ref_idx(h);
            if idx == NIL {
                return None;
            }
            let next = self.slots[idx as usize].next.load(Ordering::Acquire);
            let repl = pack(ref_gen(h).wrapping_add(1), next);
            match head.compare_exchange_weak(h, repl, Ordering::AcqRel, Ordering::Acquire) {
                Ok(_) => return Some(idx),
                Err(cur) => h = cur,
            }
        }
    }

    fn push_to(&self, head: &AtomicU64, idx: u32) {
        let mut h = head.load(Ordering::Relaxed);
        loop {
            self.slots[idx as usize].next.store(ref_idx(h), Ordering::Relaxed);
            let repl = pack(ref_gen(h).wrapping_add(1), idx);
            match head.compare_exchange_weak(h, repl, Ordering::AcqRel, Ordering::Acquire) {
                Ok(_) => return,
                Err(cur) => h = cur,
            }
        }
    }

    /// Push a value; `Err(value)` hands it back when the stack is full.
    pub fn push(&self, value: T) -> Result<(), T> {
        match self.pop_from(&self.vacant) {
            Some(idx) => {
                // SAFETY: popping `idx` off the vacant list grants
                // exclusive ownership of the value cell until the
                // `push_to` below publishes it on the full list.
                unsafe { *self.slots[idx as usize].value.get() = Some(value) };
                self.push_to(&self.full, idx);
                Ok(())
            }
            None => Err(value),
        }
    }

    /// Pop the most recently pushed value, or `None` when empty.
    pub fn pop(&self) -> Option<T> {
        let idx = self.pop_from(&self.full)?;
        // SAFETY: popping off the full list grants exclusive ownership;
        // the pusher's value write happened-before its full-list CAS.
        let v = unsafe { (*self.slots[idx as usize].value.get()).take() };
        debug_assert!(v.is_some(), "full-list slot lost its value");
        self.push_to(&self.vacant, idx);
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;
    use std::sync::Arc;

    #[test]
    fn tagged_refs_roundtrip() {
        let r = pack(7, 42);
        assert_eq!(ref_gen(r), 7);
        assert_eq!(ref_idx(r), 42);
        assert_eq!(ref_idx(pack(u32::MAX, NIL)), NIL);
    }

    #[test]
    fn segment_math_is_consistent() {
        // Every index maps into a segment whose [base, base+len) range
        // contains it, and bases tile the index space without gaps.
        let mut expect_base = 0_u32;
        for s in 0..12 {
            assert_eq!(seg_base(s), expect_base);
            expect_base += seg_len(s) as u32;
        }
        for idx in [0_u32, 1, 63, 64, 65, 191, 192, 200_000] {
            let s = seg_of(idx);
            assert!(seg_base(s) <= idx);
            assert!((idx as u64) < seg_base(s) as u64 + seg_len(s) as u64, "idx {idx} seg {s}");
        }
    }

    #[test]
    fn arena_alloc_retire_bumps_generation() {
        let a: Arena<AtomicU32> = Arena::new();
        let i = a.alloc();
        let g0 = a.slot(i).generation();
        a.retire(i);
        let j = a.alloc();
        assert_eq!(j, i, "retired slot is reused first");
        assert_eq!(a.slot(j).generation(), g0 + 1, "retire bumps the generation");
    }

    #[test]
    fn arena_grows_past_first_segment() {
        let a: Arena<AtomicU32> = Arena::new();
        let n = 500_u32; // spans segments 0..3
        let idxs: Vec<u32> = (0..n).map(|_| a.alloc()).collect();
        for (k, &i) in idxs.iter().enumerate() {
            a.slot(i).item.store(k as u32, Ordering::Relaxed);
        }
        for (k, &i) in idxs.iter().enumerate() {
            assert_eq!(a.slot(i).item.load(Ordering::Relaxed), k as u32);
        }
    }

    #[test]
    fn queue_fifo_single_thread() {
        let arena: Arena<Node<u64>> = Arena::new();
        let q = Queue::default();
        q.init(&arena);
        assert!(q.pop(&arena).is_none());
        for v in 0..100_u64 {
            q.push(&arena, v);
        }
        for v in 0..100_u64 {
            assert_eq!(q.pop(&arena), Some(v));
        }
        assert!(q.pop(&arena).is_none());
        q.teardown(&arena);
    }

    #[test]
    fn queue_nodes_recycle_through_arena() {
        let arena: Arena<Node<u64>> = Arena::new();
        let q = Queue::default();
        q.init(&arena);
        // Steady-state ping-pong must not grow the arena beyond a few
        // nodes (dummy + one value + recycling slack).
        for v in 0..10_000_u64 {
            q.push(&arena, v);
            assert_eq!(q.pop(&arena), Some(v));
        }
        assert!(
            arena.fresh.load(Ordering::Relaxed) < 16,
            "nodes must be recycled, not leaked: {}",
            arena.fresh.load(Ordering::Relaxed)
        );
        q.teardown(&arena);
    }

    #[test]
    fn queue_concurrent_mpmc_delivers_everything() {
        const PRODUCERS: usize = 4;
        const CONSUMERS: usize = 4;
        const PER: usize = 5_000;
        let arena: Arc<Arena<Node<u64>>> = Arc::new(Arena::new());
        let q = Arc::new(Queue::default());
        q.init(&arena);
        let got = Arc::new(AtomicUsize::new(0));
        let sum = Arc::new(AtomicU64::new(0));
        std::thread::scope(|s| {
            for p in 0..PRODUCERS {
                let (arena, q) = (arena.clone(), q.clone());
                s.spawn(move || {
                    for i in 0..PER {
                        q.push(&arena, (p * PER + i) as u64);
                    }
                });
            }
            for _ in 0..CONSUMERS {
                let (arena, q) = (arena.clone(), q.clone());
                let (got, sum) = (got.clone(), sum.clone());
                s.spawn(move || loop {
                    if let Some(v) = q.pop(&arena) {
                        sum.fetch_add(v, Ordering::Relaxed);
                        if got.fetch_add(1, Ordering::Relaxed) + 1 == PRODUCERS * PER {
                            return;
                        }
                    } else if got.load(Ordering::Relaxed) >= PRODUCERS * PER {
                        return;
                    } else {
                        std::hint::spin_loop();
                    }
                });
            }
        });
        let n = (PRODUCERS * PER) as u64;
        assert_eq!(got.load(Ordering::Relaxed), PRODUCERS * PER);
        assert_eq!(sum.load(Ordering::Relaxed), n * (n - 1) / 2, "every value exactly once");
    }

    #[test]
    fn queue_values_drop_on_teardown() {
        let arena: Arena<Node<Arc<()>>> = Arena::new();
        let q = Queue::default();
        q.init(&arena);
        let token = Arc::new(());
        for _ in 0..5 {
            q.push(&arena, token.clone());
        }
        assert_eq!(Arc::strong_count(&token), 6);
        q.teardown(&arena);
        assert_eq!(Arc::strong_count(&token), 1, "teardown drops queued values");
    }

    #[test]
    fn tagged_stack_lifo_and_capacity_bound() {
        let st: TaggedStack<u32> = TaggedStack::new(2);
        assert!(st.pop().is_none());
        assert!(st.push(1).is_ok());
        assert!(st.push(2).is_ok());
        assert_eq!(st.push(3), Err(3), "full stack hands the value back");
        assert_eq!(st.pop(), Some(2));
        assert_eq!(st.pop(), Some(1));
        assert!(st.pop().is_none());
    }

    #[test]
    fn tagged_stack_concurrent_push_pop() {
        const THREADS: usize = 8;
        const ROUNDS: usize = 20_000;
        let st: Arc<TaggedStack<usize>> = Arc::new(TaggedStack::new(4));
        let popped = Arc::new(AtomicUsize::new(0));
        let dropped = Arc::new(AtomicUsize::new(0));
        std::thread::scope(|s| {
            for t in 0..THREADS {
                let st = st.clone();
                let (popped, dropped) = (popped.clone(), dropped.clone());
                s.spawn(move || {
                    for i in 0..ROUNDS {
                        if (t + i) % 2 == 0 {
                            if st.push(i).is_err() {
                                dropped.fetch_add(1, Ordering::Relaxed);
                            }
                        } else if st.pop().is_some() {
                            popped.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                });
            }
        });
        let pushes_kept = THREADS * ROUNDS / 2 - dropped.load(Ordering::Relaxed);
        let left = std::iter::from_fn(|| st.pop()).count();
        let total = popped.load(Ordering::Relaxed) + left;
        assert_eq!(total, pushes_kept, "no value lost or duplicated");
    }
}
