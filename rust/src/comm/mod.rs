//! The zero-copy data plane beneath the transports and collectives.
//!
//! * [`buf`] — reference-counted byte buffers ([`buf::Buf`]) backed by a
//!   sharded, size-classed pool ([`buf::BufPool`]), plus the matching
//!   f32 staging pool ([`buf::FloatPool`]). A payload is allocated once
//!   at the producer and *sliced* — never copied — through the mailbox,
//!   the wire framing and the collective algorithms.
//! * [`split`] — disjoint mutable chunk views of one `Vec<f32>`, so the
//!   KaiTian 3-stage pipeline can stream a large tensor through its
//!   stage threads chunk by chunk without copying it apart.

pub mod buf;
pub mod split;

pub use buf::{chunk_bytes, set_chunk_bytes, Buf, BufMut, BufPool, FloatPool, PoolStats};
pub use split::{split_chunks, ChunkGroup, ChunkMut};
