//! The zero-copy data plane beneath the transports and collectives.
//!
//! * [`buf`] — reference-counted byte buffers ([`buf::Buf`]) backed by a
//!   sharded, size-classed pool ([`buf::BufPool`]), plus the matching
//!   f32 staging pool ([`buf::FloatPool`]). A payload is allocated once
//!   at the producer and *sliced* — never copied — through the mailbox,
//!   the wire framing and the collective algorithms.
//! * [`slab`] — the lock-free slab primitives beneath the hot paths: a
//!   generation-tagged slot arena with sharded atomic free lists, an
//!   MPMC queue over arena nodes, and the fixed-capacity tagged Treiber
//!   stacks that back the pool free lists.
//! * [`split`] — disjoint mutable chunk views of one `Vec<f32>`, so the
//!   KaiTian 3-stage pipeline can stream a large tensor through its
//!   stage threads chunk by chunk without copying it apart.
//! * [`tensor`] — the dtype-tagged [`tensor::CommTensor`] payloads the
//!   collective API moves (length-checked wire-format views with
//!   zero-copy `Vec<f32>` endpoints), plus the f16/bf16 scalar codecs.

pub mod buf;
pub mod slab;
pub mod split;
pub mod tensor;

pub use buf::{chunk_bytes, set_chunk_bytes, Buf, BufMut, BufPool, FloatPool, PoolStats};
pub use split::{split_chunks, ChunkGroup, ChunkMut};
pub use tensor::{with_f32_wire, with_f32_wire_ref, CommTensor, DType};
