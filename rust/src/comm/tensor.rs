//! `CommTensor` — the dtype-tagged payload currency of the collective
//! API (paper §III-A: the unified abstraction layer routes *any* payload
//! type over *any* path).
//!
//! A [`CommTensor`] is a length-checked, dtype-tagged view over flat
//! storage in **little-endian wire format** (the format the transports
//! move): element count × [`DType::size_bytes`] bytes. Storage comes in
//! three forms so the common paths stay zero-copy:
//!
//! * `F32` — an owned `Vec<f32>` (native storage; on little-endian
//!   targets native *is* the wire format). [`CommTensor::from_vec`] /
//!   [`CommTensor::into_vec`] move the vector without copying — the
//!   train loop's gradient buffers enter and leave the collective API
//!   for free.
//! * `Bytes` — owned wire bytes for any dtype (what the collective
//!   algorithms fold into in place).
//! * `View` — a zero-copy read-only view over a data-plane
//!   [`Buf`] ([`CommTensor::from_buf`]); promoted to owned bytes on
//!   first mutation (copy-on-write).
//!
//! The per-dtype elementwise reduction lives in
//! [`crate::collectives::ops::ReduceOp::fold_wire`]; the scalar codecs
//! (f16/bf16 with round-to-nearest-even, i32/u8 little-endian) live
//! here, next to the dtype they define.

use crate::comm::buf::Buf;
use crate::Result;

// ---------------------------------------------------------------------
// scalar codecs
// ---------------------------------------------------------------------

/// f32 -> IEEE binary16 bits, round-to-nearest-even (handles
/// subnormals/inf/NaN; no `half` crate in the vendored set).
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xFF) as i32;
    let mant = bits & 0x7F_FFFF;

    if exp == 0xFF {
        // Inf / NaN.
        let m = if mant != 0 { 0x0200 } else { 0 };
        return sign | 0x7C00 | m;
    }
    // Re-bias: f32 exp-127, f16 exp-15.
    let unbiased = exp - 127;
    if unbiased > 15 {
        return sign | 0x7C00; // overflow -> inf
    }
    if unbiased >= -14 {
        // Normal f16.
        let half_exp = ((unbiased + 15) as u16) << 10;
        let half_mant = (mant >> 13) as u16;
        let round_bits = mant & 0x1FFF;
        let mut out = sign | half_exp | half_mant;
        // round to nearest even
        if round_bits > 0x1000 || (round_bits == 0x1000 && (half_mant & 1) == 1) {
            out = out.wrapping_add(1);
        }
        return out;
    }
    if unbiased >= -24 {
        // Subnormal f16.
        // f16 subnormal = mant16 × 2⁻²⁴; value = full_mant × 2^(unbiased−23)
        // ⇒ mant16 = full_mant >> (−unbiased − 1).
        let full_mant = mant | 0x80_0000;
        let shift = (-unbiased - 1) as u32;
        let half_mant = (full_mant >> shift) as u16;
        let rem = full_mant & ((1 << shift) - 1);
        let half = 1_u32 << (shift - 1);
        let mut out = sign | half_mant;
        if rem > half || (rem == half && (half_mant & 1) == 1) {
            out = out.wrapping_add(1);
        }
        return out;
    }
    sign // underflow -> signed zero
}

/// IEEE binary16 bits -> f32.
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h as u32) & 0x8000) << 16;
    let exp = ((h >> 10) & 0x1F) as u32;
    let mant = (h & 0x3FF) as u32;
    let bits = match (exp, mant) {
        (0, 0) => sign,
        (0, m) => {
            // Subnormal: normalize.
            let mut e = -1_i32;
            let mut m = m;
            while m & 0x400 == 0 {
                m <<= 1;
                e -= 1;
            }
            m &= 0x3FF;
            // k shifts happened (e = −1−k); value = 1.m × 2^(−14−k)
            // ⇒ unbiased exponent = e − 13, biased = e + 114.
            sign | (((e + 114) as u32) << 23) | (m << 13)
        }
        (0x1F, 0) => sign | 0x7F80_0000,
        (0x1F, m) => sign | 0x7F80_0000 | (m << 13),
        (e, m) => sign | ((e + 127 - 15) << 23) | (m << 13),
    };
    f32::from_bits(bits)
}

/// f32 -> bfloat16 bits (truncated-exponent format), round-to-nearest-even.
pub fn f32_to_bf16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        // Keep it a NaN after truncation (quiet bit forced on).
        return ((bits >> 16) as u16) | 0x0040;
    }
    // RNE via the carry trick: add half-ULP (+1 when the kept LSB is set).
    let lsb = (bits >> 16) & 1;
    ((bits.wrapping_add(0x7FFF + lsb)) >> 16) as u16
}

/// bfloat16 bits -> f32 (exact: bf16 is a truncated f32).
pub fn bf16_bits_to_f32(h: u16) -> f32 {
    f32::from_bits((h as u32) << 16)
}

// ---------------------------------------------------------------------
// DType
// ---------------------------------------------------------------------

/// Element type of a [`CommTensor`] payload.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DType {
    /// IEEE binary32 — the training dtype.
    F32,
    /// IEEE binary16 — compressed gradients / quantized activations.
    F16,
    /// bfloat16 — truncated-f32 mixed precision.
    Bf16,
    /// 32-bit signed integers — counters, indices, token ids.
    I32,
    /// Unsigned bytes — quantized payloads (Embodied-runtime style).
    U8,
}

impl DType {
    /// Bytes per element on the wire.
    pub const fn size_bytes(self) -> usize {
        match self {
            DType::F32 | DType::I32 => 4,
            DType::F16 | DType::Bf16 => 2,
            DType::U8 => 1,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            DType::F32 => "f32",
            DType::F16 => "f16",
            DType::Bf16 => "bf16",
            DType::I32 => "i32",
            DType::U8 => "u8",
        }
    }

    /// Every supported dtype (test matrices iterate this).
    pub const ALL: [DType; 5] = [DType::F32, DType::F16, DType::Bf16, DType::I32, DType::U8];

    /// Decode element `i` of `wire` to f32 (lossless for every dtype but
    /// large-magnitude I32). Debug/test/cast convenience — the reduction
    /// hot path uses dtype-native arithmetic in `ops::fold_wire` instead.
    pub fn decode_f32(self, wire: &[u8], i: usize) -> f32 {
        let es = self.size_bytes();
        let b = &wire[i * es..(i + 1) * es];
        match self {
            DType::F32 => f32::from_le_bytes([b[0], b[1], b[2], b[3]]),
            DType::F16 => f16_bits_to_f32(u16::from_le_bytes([b[0], b[1]])),
            DType::Bf16 => bf16_bits_to_f32(u16::from_le_bytes([b[0], b[1]])),
            DType::I32 => i32::from_le_bytes([b[0], b[1], b[2], b[3]]) as f32,
            DType::U8 => b[0] as f32,
        }
    }

    /// Encode `x` into element `i` of `wire` (saturating casts for the
    /// integer dtypes).
    pub fn encode_f32(self, wire: &mut [u8], i: usize, x: f32) {
        let es = self.size_bytes();
        let b = &mut wire[i * es..(i + 1) * es];
        match self {
            DType::F32 => b.copy_from_slice(&x.to_le_bytes()),
            DType::F16 => b.copy_from_slice(&f32_to_f16_bits(x).to_le_bytes()),
            DType::Bf16 => b.copy_from_slice(&f32_to_bf16_bits(x).to_le_bytes()),
            DType::I32 => b.copy_from_slice(&(x as i32).to_le_bytes()),
            DType::U8 => b[0] = x as u8,
        }
    }
}

// ---------------------------------------------------------------------
// f32 slice <-> wire helpers
// ---------------------------------------------------------------------

/// Run `f` over the little-endian wire view of `xs`, in place. On LE
/// targets this is a pointer reinterpretation (zero-copy — the whole
/// point of keeping `DType::F32` storage native); on BE it round-trips
/// through a serialization buffer.
pub fn with_f32_wire<R>(xs: &mut [f32], f: impl FnOnce(&mut [u8]) -> R) -> R {
    if cfg!(target_endian = "little") {
        // SAFETY: u8 has no alignment requirement; the byte view spans
        // exactly the f32 slice's initialized storage; every byte
        // pattern written back is a valid f32; on LE the in-memory
        // representation *is* the wire format.
        let wire = unsafe {
            std::slice::from_raw_parts_mut(xs.as_mut_ptr() as *mut u8, xs.len() * 4)
        };
        f(wire)
    } else {
        let mut wire = vec![0_u8; xs.len() * 4];
        crate::transport::fill_f32_bytes(&mut wire, xs);
        let r = f(&mut wire);
        crate::transport::f32s_from_bytes(xs, &wire).expect("length preserved");
        r
    }
}

/// Read-only variant of [`with_f32_wire`].
pub fn with_f32_wire_ref<R>(xs: &[f32], f: impl FnOnce(&[u8]) -> R) -> R {
    if cfg!(target_endian = "little") {
        // SAFETY: see `with_f32_wire`; shared borrow, read-only.
        let wire =
            unsafe { std::slice::from_raw_parts(xs.as_ptr() as *const u8, xs.len() * 4) };
        f(wire)
    } else {
        f(&crate::transport::f32s_to_bytes(xs))
    }
}

// ---------------------------------------------------------------------
// CommTensor
// ---------------------------------------------------------------------

enum Storage {
    /// Native f32 vector (dtype is always `F32`). Invariant: this
    /// variant exists only on little-endian targets, where the native
    /// representation *is* the wire format — [`CommTensor::from_vec`]
    /// serializes eagerly on BE, so byte views never need a branch.
    F32(Vec<f32>),
    /// Owned little-endian wire bytes, any dtype.
    Bytes(Vec<u8>),
    /// Zero-copy read-only view over a data-plane [`Buf`]; promoted to
    /// `Bytes` (one copy) on first mutable access.
    View(Buf),
}

/// A dtype-tagged, length-checked flat tensor — what every collective
/// verb takes and returns.
pub struct CommTensor {
    dtype: DType,
    len: usize,
    storage: Storage,
}

impl CommTensor {
    /// Wrap an f32 vector without copying (dtype `F32`; zero-copy on
    /// little-endian targets, one serialization on BE).
    pub fn from_vec(v: Vec<f32>) -> Self {
        if cfg!(target_endian = "big") {
            let wire = crate::transport::f32s_to_bytes(&v);
            return Self {
                dtype: DType::F32,
                len: v.len(),
                storage: Storage::Bytes(wire),
            };
        }
        Self {
            dtype: DType::F32,
            len: v.len(),
            storage: Storage::F32(v),
        }
    }

    /// Recover the f32 vector. Zero-copy when the tensor kept native f32
    /// storage (the round-trip case); decodes wire bytes otherwise.
    /// Errors on non-F32 dtypes — casting is explicit via [`Self::to_f32`].
    pub fn into_vec(self) -> Result<Vec<f32>> {
        if self.dtype != DType::F32 {
            anyhow::bail!(
                "into_vec on a {} tensor; cast explicitly with to_f32()",
                self.dtype.name()
            );
        }
        match self.storage {
            Storage::F32(v) => Ok(v),
            Storage::Bytes(b) => crate::transport::bytes_to_f32s(&b),
            Storage::View(b) => crate::transport::bytes_to_f32s(&b),
        }
    }

    /// A zero-initialized tensor of `len` elements.
    pub fn zeros(dtype: DType, len: usize) -> Self {
        Self {
            dtype,
            len,
            storage: Storage::Bytes(vec![0_u8; len * dtype.size_bytes()]),
        }
    }

    /// Wrap owned wire bytes; fails unless the length is a whole number
    /// of `dtype` elements.
    pub fn from_wire(dtype: DType, bytes: Vec<u8>) -> Result<Self> {
        if bytes.len() % dtype.size_bytes() != 0 {
            anyhow::bail!(
                "{} wire bytes is not a whole number of {} elements ({} B each)",
                bytes.len(),
                dtype.name(),
                dtype.size_bytes()
            );
        }
        Ok(Self {
            dtype,
            len: bytes.len() / dtype.size_bytes(),
            storage: Storage::Bytes(bytes),
        })
    }

    /// Zero-copy view over a data-plane [`Buf`] (length-checked); the
    /// buffer is copied only if the tensor is later mutated.
    pub fn from_buf(dtype: DType, buf: Buf) -> Result<Self> {
        if buf.len() % dtype.size_bytes() != 0 {
            anyhow::bail!(
                "Buf of {} bytes is not a whole number of {} elements",
                buf.len(),
                dtype.name()
            );
        }
        Ok(Self {
            dtype,
            len: buf.len() / dtype.size_bytes(),
            storage: Storage::View(buf),
        })
    }

    /// Encode an f32 slice into `dtype` (the explicit lossy-cast
    /// entrypoint — what [`crate::backend::Fp16Relay`] stages with).
    pub fn from_f32(dtype: DType, xs: &[f32]) -> Self {
        if dtype == DType::F32 {
            return Self::from_vec(xs.to_vec());
        }
        let mut wire = vec![0_u8; xs.len() * dtype.size_bytes()];
        for (i, &x) in xs.iter().enumerate() {
            dtype.encode_f32(&mut wire, i, x);
        }
        Self {
            dtype,
            len: xs.len(),
            storage: Storage::Bytes(wire),
        }
    }

    /// Decode every element to f32 (always a copy).
    pub fn to_f32(&self) -> Vec<f32> {
        let wire = self.as_bytes();
        (0..self.len).map(|i| self.dtype.decode_f32(wire, i)).collect()
    }

    pub fn dtype(&self) -> DType {
        self.dtype
    }

    /// Element count.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn byte_len(&self) -> usize {
        self.len * self.dtype.size_bytes()
    }

    /// The little-endian wire view.
    pub fn as_bytes(&self) -> &[u8] {
        match &self.storage {
            // SAFETY: see `with_f32_wire_ref`; the F32 variant only
            // exists on LE targets (enforced in `from_vec`).
            Storage::F32(v) => unsafe {
                std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4)
            },
            Storage::Bytes(b) => b,
            Storage::View(b) => b.as_slice(),
        }
    }

    /// Mutable wire view (collectives fold into this in place). A `View`
    /// is promoted to owned bytes first (copy-on-write).
    pub fn as_bytes_mut(&mut self) -> &mut [u8] {
        if matches!(&self.storage, Storage::View(_)) {
            let owned = self.as_bytes().to_vec();
            self.storage = Storage::Bytes(owned);
        }
        match &mut self.storage {
            // SAFETY: see `with_f32_wire`; LE-only variant.
            Storage::F32(v) => unsafe {
                std::slice::from_raw_parts_mut(v.as_mut_ptr() as *mut u8, v.len() * 4)
            },
            Storage::Bytes(b) => b,
            Storage::View(_) => unreachable!("views were promoted above"),
        }
    }

    /// Freeze into a data-plane [`Buf`] (zero-copy for owned bytes and
    /// views; native f32 storage pays one serialization — a `Vec<f32>`
    /// cannot be re-tagged as `Vec<u8>`, the allocator layouts differ).
    pub fn freeze(self) -> Buf {
        match self.storage {
            Storage::F32(v) => Buf::from_vec(crate::transport::f32s_to_bytes(&v)),
            Storage::Bytes(b) => Buf::from_vec(b),
            Storage::View(b) => b,
        }
    }

    /// Consume the tensor and take its wire bytes (zero-copy for owned
    /// bytes; serializes f32 storage, copies views). Lets callers hand a
    /// pooled staging vector back to `BufPool::put_vec` when the tensor
    /// was built over one.
    pub fn into_wire(self) -> Vec<u8> {
        match self.storage {
            Storage::F32(v) => crate::transport::f32s_to_bytes(&v),
            Storage::Bytes(b) => b,
            Storage::View(b) => b.as_slice().to_vec(),
        }
    }

    /// Consume the tensor and return its storage to the global pools
    /// (f32 vectors to the [`crate::comm::buf::FloatPool`], owned byte
    /// buffers to the [`crate::comm::buf::BufPool`]). Collectives that
    /// consume an input tensor and emit a different output (e.g.
    /// reduce-scatter's shard) call this so pooled hand-off buffers keep
    /// cycling instead of falling out of the data plane.
    pub fn recycle(self) {
        match self.storage {
            Storage::F32(v) => crate::comm::buf::FloatPool::global().put(v),
            Storage::Bytes(b) => crate::comm::buf::BufPool::global().put_vec(b),
            Storage::View(_) => {}
        }
    }

    /// A new tensor holding elements `start..end` (copy of the range).
    pub fn slice(&self, start: usize, end: usize) -> Result<CommTensor> {
        anyhow::ensure!(
            start <= end && end <= self.len,
            "slice {start}..{end} out of bounds for {} elements",
            self.len
        );
        let es = self.dtype.size_bytes();
        let bytes = self.as_bytes()[start * es..end * es].to_vec();
        CommTensor::from_wire(self.dtype, bytes)
    }

    /// Cast to another dtype through f32 (lossy for narrow targets).
    pub fn cast(&self, dtype: DType) -> CommTensor {
        if dtype == self.dtype {
            return CommTensor {
                dtype: self.dtype,
                len: self.len,
                storage: Storage::Bytes(self.as_bytes().to_vec()),
            };
        }
        CommTensor::from_f32(dtype, &self.to_f32())
    }
}

impl Clone for CommTensor {
    fn clone(&self) -> Self {
        let storage = match &self.storage {
            Storage::F32(v) => Storage::F32(v.clone()),
            Storage::Bytes(b) => Storage::Bytes(b.clone()),
            Storage::View(b) => Storage::View(b.clone()),
        };
        Self {
            dtype: self.dtype,
            len: self.len,
            storage,
        }
    }
}

impl std::fmt::Debug for CommTensor {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("CommTensor")
            .field("dtype", &self.dtype.name())
            .field("len", &self.len)
            .finish()
    }
}

impl PartialEq for CommTensor {
    fn eq(&self, other: &Self) -> bool {
        self.dtype == other.dtype && self.as_bytes() == other.as_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f16_roundtrip_exact_for_representable() {
        for x in [0.0_f32, -0.0, 1.0, -1.0, 0.5, 2.0, 65504.0, -65504.0] {
            assert_eq!(f16_bits_to_f32(f32_to_f16_bits(x)), x, "{x}");
        }
    }

    #[test]
    fn bf16_roundtrip_and_rounding() {
        for x in [0.0_f32, 1.0, -2.5, 1e20, -1e-20, 3.140625] {
            let back = bf16_bits_to_f32(f32_to_bf16_bits(x));
            let rel = ((back - x) / x.abs().max(1e-30)).abs();
            assert!(rel < 1e-2, "{x} -> {back}");
        }
        assert!(bf16_bits_to_f32(f32_to_bf16_bits(f32::NAN)).is_nan());
        assert!(bf16_bits_to_f32(f32_to_bf16_bits(f32::INFINITY)).is_infinite());
        // Values with <= 8 mantissa bits survive exactly.
        assert_eq!(bf16_bits_to_f32(f32_to_bf16_bits(1.5)), 1.5);
    }

    #[test]
    fn from_vec_into_vec_roundtrip_is_exact() {
        let xs = vec![1.5_f32, -2.25, 0.0, f32::MAX, f32::MIN_POSITIVE];
        let t = CommTensor::from_vec(xs.clone());
        assert_eq!(t.dtype(), DType::F32);
        assert_eq!(t.len(), 5);
        assert_eq!(t.byte_len(), 20);
        assert_eq!(t.into_vec().unwrap(), xs);
    }

    #[test]
    fn wire_length_checked() {
        assert!(CommTensor::from_wire(DType::F32, vec![0; 6]).is_err());
        assert!(CommTensor::from_wire(DType::F16, vec![0; 6]).is_ok());
        assert!(CommTensor::from_wire(DType::U8, vec![0; 3]).is_ok());
        let buf = Buf::from_vec(vec![0; 10]);
        assert!(CommTensor::from_buf(DType::I32, buf.clone()).is_err());
        assert!(CommTensor::from_buf(DType::F16, buf).is_ok());
    }

    #[test]
    fn buf_view_is_copy_on_write() {
        let buf = Buf::from_vec(vec![1, 0, 2, 0]);
        let mut t = CommTensor::from_buf(DType::F16, buf.clone()).unwrap();
        assert_eq!(t.len(), 2);
        assert_eq!(t.as_bytes(), buf.as_slice());
        t.as_bytes_mut()[0] = 9;
        assert_eq!(t.as_bytes()[0], 9);
        assert_eq!(buf.as_slice()[0], 1, "the shared Buf is untouched");
    }

    #[test]
    fn encode_decode_every_dtype() {
        let xs = [0.0_f32, 1.0, -2.0, 100.0];
        for dtype in DType::ALL {
            let t = CommTensor::from_f32(dtype, &xs);
            assert_eq!(t.len(), xs.len());
            assert_eq!(t.byte_len(), xs.len() * dtype.size_bytes());
            let back = t.to_f32();
            for (i, (&a, &b)) in xs.iter().zip(&back).enumerate() {
                if dtype == DType::U8 {
                    // u8 saturates negatives to 0 via the `as` cast.
                    let expect = if a < 0.0 { 0.0 } else { a };
                    assert_eq!(b, expect, "{} elem {i}", dtype.name());
                } else {
                    assert_eq!(b, a, "{} elem {i} (exactly representable)", dtype.name());
                }
            }
        }
    }

    #[test]
    fn into_vec_rejects_non_f32() {
        let t = CommTensor::from_f32(DType::F16, &[1.0, 2.0]);
        assert!(t.into_vec().is_err());
    }

    #[test]
    fn slice_and_cast() {
        let t = CommTensor::from_vec(vec![1.0, 2.0, 3.0, 4.0]);
        let s = t.slice(1, 3).unwrap();
        assert_eq!(s.to_f32(), vec![2.0, 3.0]);
        assert!(t.slice(2, 5).is_err());
        let h = t.cast(DType::F16);
        assert_eq!(h.dtype(), DType::F16);
        assert_eq!(h.to_f32(), vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn wire_helpers_roundtrip() {
        let mut xs = vec![1.0_f32, -2.5, 3.25];
        let wire_copy = with_f32_wire_ref(&xs, |w| w.to_vec());
        assert_eq!(wire_copy, crate::transport::f32s_to_bytes(&xs));
        with_f32_wire(&mut xs, |w| {
            // Overwrite the first element with 7.0 in wire form.
            w[0..4].copy_from_slice(&7.0_f32.to_le_bytes());
        });
        assert_eq!(xs, vec![7.0, -2.5, 3.25]);
    }

    #[test]
    fn zeros_and_freeze() {
        let t = CommTensor::zeros(DType::I32, 3);
        assert_eq!(t.byte_len(), 12);
        assert_eq!(t.to_f32(), vec![0.0; 3]);
        let buf = t.freeze();
        assert_eq!(buf.len(), 12);
    }
}
