//! Bounded-staleness sharded parameter server — the `ps_async` gradient
//! sync mode (ROADMAP item 5).
//!
//! Bulk-synchronous data parallelism pays the straggler tax every step:
//! between the scheduler's rebalances, each all-reduce barrier runs at
//! the pace of the slowest device. This module replaces the barrier with
//! a *stale-synchronous-parallel* (SSP) protocol:
//!
//! * Parameters are hash-partitioned **by bucket** across shards hosted
//!   on the group-leader ranks ([`ShardPlan`]; bucket `b` → shard
//!   `b % S`, shard `s` → leader `s % L`) — the same ranks that already
//!   carry the host relay, so parameter traffic rides the staging path
//!   the paper mandates for cross-vendor bytes.
//! * Each step (a *version*), every worker **pushes** its local gradient
//!   sum for each shard's owned ranges, then issues a **pull** that it
//!   only completes at the top of the *next* step — overlapping the
//!   server round-trip with the next forward pass.
//! * The server applies version `v` only once *all* workers' pushes for
//!   `v` have arrived, summing the per-worker gradients in rank order
//!   0..W-1 and stepping SGD with the same fused update the synchronous
//!   modes use — so `K = 0` degenerates to fully synchronous SGD and
//!   (on two-rank clusters, where two-operand float addition is
//!   order-independent) bitwise-matches the sharded mode.
//! * The **bounded-staleness gate**: a pull for version `v` is granted
//!   only when `v - min_w(pushed[w]) <= K`. Fast workers may run at most
//!   `K` versions ahead of the slowest rank; the slowest rank itself is
//!   never gated, so the protocol cannot deadlock. Blocked remote pulls
//!   simply *are* the reply message not having been sent yet — the
//!   worker's deferred `recv` parks on the mailbox like any other flow.
//!
//! Wire protocol (all-f32 frames over the `ps` tag namespace, strict
//! PUSH-then-CTRL alternation per `(worker, shard, version)` so the
//! server always knows the next frame's length):
//!
//! ```text
//! worker → host  PUSH  = [0.0, version, grads…owned]        (2 + E)
//! worker → host  CTRL  = [verb, version]                    (2)
//!                        verb 1.0 = PULL, 2.0 = PULL_FINAL
//! host → worker  PULL reply       = [min_pushed, pushed[0..W], params…]            (1 + W + E)
//! host → worker  PULL_FINAL reply = [min_pushed, pushed[0..W], params…, momentum…] (1 + W + 2E)
//! ```
//!
//! Versions are exact in f32 (training runs are far below 2^24 steps).
//! The pushed-version vector piggybacks on every reply, giving each
//! worker the cluster-wide version lag for the report JSON.
//!
//! The server also counts pushes per worker ([`PsHub::load_window`]):
//! in `ps_async` mode the scheduler consumes these *server-observed push
//! rates* as its load signal instead of the per-step timings a barrier
//! would have produced.
//!
//! Knobs: `--staleness` / `KAITIAN_STALENESS` (window `K`) and
//! `--ps_shards` / `KAITIAN_PS_SHARDS` (shard count; `0` = one per
//! group leader), both validated by [`crate::util::env::parse_or_warn`].

use std::collections::BTreeMap;
use std::ops::Range;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::comm::tensor::{CommTensor, DType};
use crate::group::ProcessGroup;
use crate::train::loop_::sgd_update_shard;
use crate::train::LrSchedule;
use crate::util::env::env_or_warn;
use crate::Result;

/// Environment override for the staleness window `K`.
pub const ENV_STALENESS: &str = "KAITIAN_STALENESS";
/// Environment override for the parameter-server shard count.
pub const ENV_PS_SHARDS: &str = "KAITIAN_PS_SHARDS";
/// Default staleness window when neither CLI nor env sets one.
pub const DEFAULT_STALENESS: usize = 1;

/// `KAITIAN_STALENESS`, validated (garbage warns and falls back).
pub fn staleness_from_env() -> usize {
    env_or_warn(ENV_STALENESS, DEFAULT_STALENESS)
}

/// `KAITIAN_PS_SHARDS`, validated (`0` = one shard per group leader).
pub fn ps_shards_from_env() -> usize {
    env_or_warn(ENV_PS_SHARDS, 0)
}

// --- tag namespace ----------------------------------------------------

/// Base of the `ps` user-tag namespace: far above the p2p tags the
/// collectives use, and well inside the 32-bit user-tag space that
/// `collectives::chunk::ptp_tag` maps disjointly from collective op
/// tags.
pub const PS_TAG_BASE: u32 = 1 << 30;

/// Request tag (worker → host) for one shard's flows. FIFO per
/// `(peer, tag)` keeps each worker's PUSH/CTRL alternation ordered.
pub fn req_tag(shard: usize) -> u32 {
    PS_TAG_BASE | ((shard as u32) << 2)
}

/// Reply tag (host → worker) for one shard's flows.
pub fn rep_tag(shard: usize) -> u32 {
    PS_TAG_BASE | ((shard as u32) << 2) | 1
}

// --- wire encoding ----------------------------------------------------

/// CTRL verb: pull current params (reply `1 + W + E` f32s).
pub const VERB_PULL: f32 = 1.0;
/// CTRL verb: final pull — params *and* momentum (reply `1 + W + 2E`).
pub const VERB_PULL_FINAL: f32 = 2.0;
/// PUSH frame header length (`[0.0, version]`).
pub const PUSH_HDR: usize = 2;
/// CTRL frame length (`[verb, version]`).
pub const CTRL_LEN: usize = 2;

/// Build one PUSH frame: `[0.0, version, grads…]`.
pub fn encode_push(version: u64, grads: &[f32]) -> Vec<f32> {
    let mut out = Vec::with_capacity(PUSH_HDR + grads.len());
    out.push(0.0);
    out.push(version as f32);
    out.extend_from_slice(grads);
    out
}

/// Build one CTRL frame: `[verb, version]`.
pub fn encode_ctrl(verb: f32, version: u64) -> Vec<f32> {
    vec![verb, version as f32]
}

// --- sharding ---------------------------------------------------------

/// One shard: its host rank and the parameter ranges it owns.
#[derive(Debug, Clone)]
pub struct ShardSpec {
    /// The group-leader rank hosting this shard.
    pub host: usize,
    /// The (bucket) ranges of the flat parameter vector this shard owns.
    pub ranges: Vec<Range<usize>>,
    /// Total owned elements (`Σ ranges[i].len()`).
    pub elems: usize,
}

/// The bucket → shard → host partition of the flat parameter vector.
///
/// Built from the *same* bucket ranges the synchronous sync paths use
/// ([`crate::ddp::DdpEngine::sync_ranges`]), so ps traffic has the same
/// granularity as the collectives it replaces.
#[derive(Debug, Clone)]
pub struct ShardPlan {
    n_params: usize,
    shards: Vec<ShardSpec>,
}

impl ShardPlan {
    /// Partition `ranges` (covering `0..n_params`) across `shards`
    /// shards hosted round-robin on `leaders`. `shards == 0` means one
    /// shard per leader; the count is clamped to the number of ranges so
    /// no shard is empty.
    pub fn build(
        n_params: usize,
        ranges: &[Range<usize>],
        leaders: &[usize],
        shards: usize,
    ) -> Result<Self> {
        anyhow::ensure!(!leaders.is_empty(), "ps: no group leaders to host shards");
        let want = if shards == 0 { leaders.len() } else { shards };
        let s = want.min(ranges.len()).max(1);
        let mut specs: Vec<ShardSpec> = (0..s)
            .map(|i| ShardSpec {
                host: leaders[i % leaders.len()],
                ranges: Vec::new(),
                elems: 0,
            })
            .collect();
        for (b, r) in ranges.iter().enumerate() {
            let spec = &mut specs[b % s];
            spec.ranges.push(r.clone());
            spec.elems += r.len();
        }
        Ok(Self {
            n_params,
            shards: specs,
        })
    }

    /// Flat parameter-vector length this plan partitions.
    pub fn n_params(&self) -> usize {
        self.n_params
    }

    /// Number of shards.
    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// The rank hosting `shard`.
    pub fn host(&self, shard: usize) -> usize {
        self.shards[shard].host
    }

    /// Elements owned by `shard`.
    pub fn shard_elems(&self, shard: usize) -> usize {
        self.shards[shard].elems
    }

    /// The full spec of `shard`.
    pub fn spec(&self, shard: usize) -> &ShardSpec {
        &self.shards[shard]
    }

    /// Shards hosted on `rank` (empty for non-leaders).
    pub fn hosted_shards(&self, rank: usize) -> Vec<usize> {
        (0..self.shards.len())
            .filter(|&s| self.shards[s].host == rank)
            .collect()
    }

    /// Copy `shard`'s owned ranges out of the flat vector, concatenated
    /// in range order (the wire layout of PUSH payloads and replies).
    pub fn gather(&self, shard: usize, flat: &[f32]) -> Vec<f32> {
        let spec = &self.shards[shard];
        let mut out = Vec::with_capacity(spec.elems);
        for r in &spec.ranges {
            out.extend_from_slice(&flat[r.clone()]);
        }
        out
    }

    /// Scatter a concatenated shard payload back into the flat vector.
    pub fn scatter(&self, shard: usize, data: &[f32], flat: &mut [f32]) {
        let spec = &self.shards[shard];
        debug_assert_eq!(data.len(), spec.elems);
        let mut off = 0;
        for r in &spec.ranges {
            flat[r.clone()].copy_from_slice(&data[off..off + r.len()]);
            off += r.len();
        }
    }
}

// --- server hyperparameters -------------------------------------------

/// The optimizer state the server needs to apply versions: the same
/// schedule and scaling the synchronous loop uses, so `K = 0` is
/// *bitwise* the synchronous update.
#[derive(Debug, Clone, Copy)]
pub struct PsHyper {
    /// Step-decay learning-rate schedule (per epoch).
    pub schedule: LrSchedule,
    /// SGD momentum coefficient.
    pub momentum: f32,
    /// L2 weight decay.
    pub weight_decay: f32,
    /// Gradient scale (`1 / global_batch`; pushes carry *sums*).
    pub grad_scale: f32,
    /// Steps per epoch (maps a version to its schedule epoch).
    pub steps_per_epoch: usize,
    /// The staleness window `K`.
    pub staleness: usize,
}

impl PsHyper {
    /// `[lr, momentum, weight_decay, grad_scale]` for applying `version`.
    pub fn hyper_at(&self, version: u64) -> [f32; 4] {
        let epoch = version as usize / self.steps_per_epoch.max(1);
        [
            self.schedule.lr_at(epoch),
            self.momentum,
            self.weight_decay,
            self.grad_scale,
        ]
    }
}

// --- the hub ----------------------------------------------------------

/// Stats returned with every granted pull (fed into the per-rank
/// `ps_wait_s` / `ps_lag` report fields).
#[derive(Debug, Clone, Default)]
pub struct PsPullStats {
    /// Wall-clock seconds this pull was gated (or spent blocked in
    /// `recv` for remote shards).
    pub wait_s: f64,
    /// `version - min_w(pushed[w])` at grant time (`<= K` by the gate).
    pub lag: u64,
    /// Snapshot of every worker's highest pushed version (`-1` = none).
    pub versions: Vec<i64>,
    /// Highest version fully applied to the returned params.
    pub applied: i64,
}

impl PsPullStats {
    /// Fold another shard's grant into a per-step aggregate: waits add
    /// (they are serial on the caller), lags take the max.
    pub fn fold(&mut self, other: &PsPullStats) {
        self.wait_s += other.wait_s;
        self.lag = self.lag.max(other.lag);
        if other.versions.len() > self.versions.len() {
            self.versions = other.versions.clone();
        }
        self.applied = self.applied.max(other.applied);
    }
}

/// One shard's authoritative optimizer state.
struct ShardState {
    /// Owned params, concatenated in range order.
    params: Vec<f32>,
    /// Owned momentum, same layout.
    momentum: Vec<f32>,
    /// Highest version pushed per worker (`-1` = none yet).
    pushed: Vec<i64>,
    /// Buffered pushes for versions not yet complete.
    pending: BTreeMap<u64, Vec<Option<Vec<f32>>>>,
    /// Highest version fully applied (`-1` = initial params).
    applied: i64,
}

struct ShardSlot {
    state: Mutex<ShardState>,
    cv: Condvar,
}

/// Server-side push-rate accounting (the scheduler's load signal in
/// `ps_async` mode).
struct PsLoad {
    counts: Vec<AtomicU64>,
    window: Mutex<(Vec<u64>, Instant)>,
}

/// The in-process parameter-server hub: every shard's state plus the
/// staleness gate. One hub is shared (via `Arc`) by all rank threads;
/// co-located workers push/pull through direct calls, remote workers
/// through [`PsHub::serve_remote`] sessions speaking the wire protocol
/// over real p2p sends — so cross-host traffic is genuinely priced.
pub struct PsHub {
    plan: ShardPlan,
    hyper: PsHyper,
    workers: usize,
    slots: Vec<ShardSlot>,
    load: PsLoad,
}

/// Upper bound on a single gate/serve wait; turns protocol bugs into
/// errors instead of hangs.
const GATE_TIMEOUT: Duration = Duration::from_secs(120);

impl PsHub {
    /// Build the hub with the initial model state (`params` /
    /// `momentum` are the full flat vectors; each shard copies out its
    /// owned ranges). `workers` is the world size.
    pub fn new(
        plan: ShardPlan,
        hyper: PsHyper,
        workers: usize,
        params: &[f32],
        momentum: &[f32],
    ) -> Arc<Self> {
        let slots = (0..plan.num_shards())
            .map(|s| ShardSlot {
                state: Mutex::new(ShardState {
                    params: plan.gather(s, params),
                    momentum: plan.gather(s, momentum),
                    pushed: vec![-1; workers],
                    pending: BTreeMap::new(),
                    applied: -1,
                }),
                cv: Condvar::new(),
            })
            .collect();
        let load = PsLoad {
            counts: (0..workers).map(|_| AtomicU64::new(0)).collect(),
            window: Mutex::new((vec![0; workers], Instant::now())),
        };
        Arc::new(Self {
            plan,
            hyper,
            workers,
            slots,
            load,
        })
    }

    /// The partition this hub serves.
    pub fn plan(&self) -> &ShardPlan {
        &self.plan
    }

    /// The server's optimizer hyperparameters.
    pub fn hyper(&self) -> &PsHyper {
        &self.hyper
    }

    /// Accumulate one worker's gradient sum for `(shard, version)` and
    /// apply every version that just became complete, in version order,
    /// summing worker contributions in rank order 0..W-1 (deterministic
    /// arithmetic regardless of arrival order).
    pub fn push(&self, shard: usize, worker: usize, version: u64, grads: Vec<f32>) -> Result<()> {
        anyhow::ensure!(
            grads.len() == self.plan.shard_elems(shard),
            "ps shard {shard}: push of {} elems, owns {}",
            grads.len(),
            self.plan.shard_elems(shard)
        );
        let slot = &self.slots[shard];
        {
            let mut st = slot.state.lock().unwrap();
            anyhow::ensure!(
                st.pushed[worker] + 1 == version as i64,
                "ps shard {shard}: worker {worker} pushed version {version} after {}",
                st.pushed[worker]
            );
            st.pushed[worker] = version as i64;
            st.pending
                .entry(version)
                .or_insert_with(|| vec![None; self.workers])[worker] = Some(grads);
            self.apply_ready(&mut st);
        }
        self.load.counts[worker].fetch_add(1, Ordering::Relaxed);
        slot.cv.notify_all();
        Ok(())
    }

    /// Apply every complete pending version in order.
    fn apply_ready(&self, st: &mut ShardState) {
        loop {
            let next = (st.applied + 1) as u64;
            match st.pending.get(&next) {
                Some(entry) if entry.iter().all(Option::is_some) => {}
                _ => return,
            }
            let entry = st.pending.remove(&next).expect("checked above");
            let mut it = entry.into_iter().map(|g| g.expect("checked above"));
            let mut sum = it.next().expect("at least one worker");
            for g in it {
                for (a, b) in sum.iter_mut().zip(&g) {
                    *a += b;
                }
            }
            sgd_update_shard(
                &mut st.params,
                &mut st.momentum,
                &sum,
                self.hyper.hyper_at(next),
            );
            st.applied = next as i64;
        }
    }

    /// Pull `shard`'s params for a worker at `version`, blocking on the
    /// bounded-staleness gate: granted only once
    /// `version - min_w(pushed[w]) <= K`. The caller must have pushed
    /// `version` first (the strict PUSH→CTRL alternation guarantees it),
    /// so the slowest worker always passes immediately.
    ///
    /// Invariant on grant: the returned params include every version up
    /// to at least `version - K` (`stats.applied >= version - K`).
    pub fn pull(&self, shard: usize, version: u64) -> Result<(Vec<f32>, PsPullStats)> {
        let t0 = Instant::now();
        let slot = &self.slots[shard];
        let k = self.hyper.staleness as i64;
        let mut st = slot.state.lock().unwrap();
        loop {
            let min = st.pushed.iter().copied().min().unwrap_or(-1);
            if version as i64 - min <= k {
                break;
            }
            let (guard, timeout) = slot.cv.wait_timeout(st, GATE_TIMEOUT).unwrap();
            st = guard;
            anyhow::ensure!(
                !timeout.timed_out(),
                "ps shard {shard}: pull gate timed out at version {version}"
            );
        }
        let min = st.pushed.iter().copied().min().unwrap_or(-1);
        debug_assert!(st.applied >= version as i64 - k, "staleness invariant");
        let stats = PsPullStats {
            wait_s: t0.elapsed().as_secs_f64(),
            lag: (version as i64 - min).max(0) as u64,
            versions: st.pushed.clone(),
            applied: st.applied,
        };
        Ok((st.params.clone(), stats))
    }

    /// Final pull: wait for `last_version` to be fully applied, then
    /// return the authoritative `(params, momentum)` for the shard.
    pub fn pull_final(&self, shard: usize, last_version: u64) -> Result<(Vec<f32>, Vec<f32>)> {
        let slot = &self.slots[shard];
        let mut st = slot.state.lock().unwrap();
        while st.applied < last_version as i64 {
            let (guard, timeout) = slot.cv.wait_timeout(st, GATE_TIMEOUT).unwrap();
            st = guard;
            anyhow::ensure!(
                !timeout.timed_out(),
                "ps shard {shard}: final pull timed out at version {last_version}"
            );
        }
        Ok((st.params.clone(), st.momentum.clone()))
    }

    /// Serve one remote worker's flows for one shard, speaking the wire
    /// protocol over `pg` (the host rank's process group). Runs until
    /// the worker's `PULL_FINAL`. Spawn one session per
    /// `(hosted shard, remote worker)` pair.
    pub fn serve_remote(&self, pg: &dyn ProcessGroup, shard: usize, worker: usize) -> Result<()> {
        let elems = self.plan.shard_elems(shard);
        let (req, rep) = (req_tag(shard), rep_tag(shard));
        loop {
            let (frame, _) = pg.recv(DType::F32, PUSH_HDR + elems, worker, req)?;
            let frame = frame.into_vec()?;
            anyhow::ensure!(
                frame[0] == 0.0,
                "ps shard {shard}: expected PUSH verb, got {}",
                frame[0]
            );
            let version = frame[1] as u64;
            self.push(shard, worker, version, frame[PUSH_HDR..].to_vec())?;

            let (ctrl, _) = pg.recv(DType::F32, CTRL_LEN, worker, req)?;
            let ctrl = ctrl.into_vec()?;
            let (verb, v) = (ctrl[0], ctrl[1] as u64);
            anyhow::ensure!(
                v == version,
                "ps shard {shard}: CTRL version {v} after PUSH {version}"
            );
            if verb == VERB_PULL_FINAL {
                let (params, momentum) = self.pull_final(shard, version)?;
                let mut reply = Vec::with_capacity(1 + self.workers + 2 * elems);
                // After PULL_FINAL every worker has pushed the last
                // version, so the piggybacked vector is uniform.
                reply.resize(1 + self.workers, version as f32);
                reply.extend_from_slice(&params);
                reply.extend_from_slice(&momentum);
                let t = CommTensor::from_vec(reply);
                pg.send(&t, worker, rep)?;
                t.recycle();
                return Ok(());
            }
            anyhow::ensure!(
                verb == VERB_PULL,
                "ps shard {shard}: unknown CTRL verb {verb}"
            );
            let (params, stats) = self.pull(shard, version)?;
            let mut reply = Vec::with_capacity(1 + self.workers + elems);
            reply.push((version as i64 - stats.lag as i64) as f32);
            reply.extend(stats.versions.iter().map(|&x| x as f32));
            reply.extend_from_slice(&params);
            let t = CommTensor::from_vec(reply);
            pg.send(&t, worker, rep)?;
            t.recycle();
        }
    }

    /// Drain the push-rate window: per-worker *per-sample seconds*
    /// proxies since the previous call (`None` when a worker pushed
    /// nothing in the window, or has no allocation). This is the load
    /// signal `sched::controller` consumes in `ps_async` mode: a slow
    /// device pushes fewer versions per wall-clock second, so its
    /// modeled per-sample time rises and the allocator shifts batch
    /// share away from it — no barrier-timed observations needed.
    pub fn load_window(&self, alloc: &[usize]) -> Vec<Option<f64>> {
        let shards = self.plan.num_shards().max(1) as f64;
        let mut w = self.load.window.lock().unwrap();
        let dt = w.1.elapsed().as_secs_f64();
        let mut out = Vec::with_capacity(self.workers);
        for r in 0..self.workers {
            let now = self.load.counts[r].load(Ordering::Relaxed);
            let delta = now - w.0[r];
            w.0[r] = now;
            let versions = delta as f64 / shards;
            let b = alloc.get(r).copied().unwrap_or(0);
            if versions <= 0.0 || b == 0 || dt <= 0.0 {
                out.push(None);
            } else {
                out.push(Some(dt / (versions * b as f64)));
            }
        }
        w.1 = Instant::now();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::env::parse_or_warn;

    fn plan(n: usize, bucket: usize, leaders: &[usize], shards: usize) -> ShardPlan {
        let ranges: Vec<Range<usize>> = (0..n)
            .step_by(bucket)
            .map(|s| s..(s + bucket).min(n))
            .collect();
        ShardPlan::build(n, &ranges, leaders, shards).unwrap()
    }

    fn hyper(k: usize) -> PsHyper {
        PsHyper {
            schedule: LrSchedule::new(0.1, 0.1, 20),
            momentum: 0.9,
            weight_decay: 5e-4,
            grad_scale: 1.0 / 8.0,
            steps_per_epoch: 10,
            staleness: k,
        }
    }

    #[test]
    fn shard_plan_partitions_all_params_disjointly() {
        let p = plan(1003, 128, &[0, 2], 0);
        assert_eq!(p.num_shards(), 2);
        let mut seen = vec![false; 1003];
        for s in 0..p.num_shards() {
            assert_eq!(p.host(s), [0, 2][s]);
            for r in &p.spec(s).ranges {
                for i in r.clone() {
                    assert!(!seen[i], "param {i} owned twice");
                    seen[i] = true;
                }
            }
        }
        assert!(seen.iter().all(|&x| x), "every param owned exactly once");
        assert_eq!(p.hosted_shards(0), vec![0]);
        assert_eq!(p.hosted_shards(1), Vec::<usize>::new());
    }

    #[test]
    fn shard_count_clamps_to_ranges_and_zero_means_leaders() {
        // 3 ranges, 2 leaders, asking for 8 shards -> clamp to 3.
        let p = plan(300, 100, &[0, 1], 8);
        assert_eq!(p.num_shards(), 3);
        assert_eq!((p.host(0), p.host(1), p.host(2)), (0, 1, 0));
        // shards=0 -> one per leader.
        assert_eq!(plan(300, 100, &[0, 1], 0).num_shards(), 2);
    }

    #[test]
    fn gather_scatter_round_trip() {
        let p = plan(517, 64, &[1, 3], 3);
        let flat: Vec<f32> = (0..517).map(|i| i as f32 * 0.5).collect();
        let mut rebuilt = vec![0.0_f32; 517];
        for s in 0..p.num_shards() {
            let owned = p.gather(s, &flat);
            assert_eq!(owned.len(), p.shard_elems(s));
            p.scatter(s, &owned, &mut rebuilt);
        }
        assert_eq!(rebuilt, flat);
    }

    #[test]
    fn tag_namespace_is_disjoint_per_shard_and_direction() {
        let mut tags = std::collections::BTreeSet::new();
        for s in 0..64 {
            assert!(tags.insert(req_tag(s)));
            assert!(tags.insert(rep_tag(s)));
            assert!(req_tag(s) >= PS_TAG_BASE);
        }
    }

    #[test]
    fn versions_are_exact_in_f32() {
        for v in [0_u64, 1, 9_750, 100_000, (1 << 24) - 1] {
            let f = v as f32;
            assert_eq!(f as u64, v, "version {v} must round-trip through f32");
        }
    }

    #[test]
    fn push_frame_round_trips() {
        let f = encode_push(9_750, &[1.5, -2.25]);
        assert_eq!(f.len(), PUSH_HDR + 2);
        assert_eq!(f[0], 0.0);
        assert_eq!(f[1] as u64, 9_750);
        assert_eq!(&f[PUSH_HDR..], &[1.5, -2.25]);
        let c = encode_ctrl(VERB_PULL_FINAL, 19);
        assert_eq!(c, vec![2.0, 19.0]);
    }

    /// Deterministic per-(worker, version) gradient sum.
    fn grad(worker: usize, version: u64, n: usize) -> Vec<f32> {
        (0..n)
            .map(|i| ((i + worker * 7) % 13) as f32 * 0.25 - version as f32 * 0.001)
            .collect()
    }

    /// Serial reference: apply every version in order, summing workers
    /// in rank order — what the hub must compute regardless of arrival
    /// interleaving.
    fn serial_reference(
        p: &ShardPlan,
        h: &PsHyper,
        workers: usize,
        versions: u64,
        n: usize,
    ) -> (Vec<f32>, Vec<f32>) {
        let mut params = vec![0.5_f32; n];
        let mut momentum = vec![0.0_f32; n];
        for v in 0..versions {
            let mut sum = grad(0, v, n);
            for w in 1..workers {
                for (a, b) in sum.iter_mut().zip(&grad(w, v, n)) {
                    *a += b;
                }
            }
            for s in 0..p.num_shards() {
                let mut ps = p.gather(s, &params);
                let mut ms = p.gather(s, &momentum);
                let gs = p.gather(s, &sum);
                sgd_update_shard(&mut ps, &mut ms, &gs, h.hyper_at(v));
                p.scatter(s, &ps, &mut params);
                p.scatter(s, &ms, &mut momentum);
            }
        }
        (params, momentum)
    }

    #[test]
    fn staleness_gate_invariant_holds_under_concurrency() {
        // Property: no pull is ever granted with the returned params
        // older than `version - K`, and the observed lag never exceeds
        // K — under 3 concurrent workers with a deliberate straggler.
        let (n, workers, versions, k) = (96, 3, 40_u64, 2);
        let p = plan(n, 16, &[0, 1], 0);
        let h = hyper(k);
        let params = vec![0.5_f32; n];
        let momentum = vec![0.0_f32; n];
        let hub = PsHub::new(p.clone(), h, workers, &params, &momentum);
        std::thread::scope(|s| {
            for w in 0..workers {
                let hub = &hub;
                let p = &p;
                s.spawn(move || {
                    for v in 0..versions {
                        if w == 0 {
                            // Straggler: let the others run ahead.
                            std::thread::sleep(Duration::from_micros(300));
                        }
                        let g = grad(w, v, n);
                        for shard in 0..p.num_shards() {
                            hub.push(shard, w, v, p.gather(shard, &g)).unwrap();
                        }
                        for shard in 0..p.num_shards() {
                            let (_, stats) = hub.pull(shard, v).unwrap();
                            assert!(
                                stats.applied >= v as i64 - k as i64,
                                "worker {w} saw version {} at step {v} (K={k})",
                                stats.applied
                            );
                            assert!(stats.lag <= k as u64, "lag {} > K", stats.lag);
                        }
                    }
                });
            }
        });
        // Regardless of interleaving, the final state is the serial one.
        let (want_p, want_m) = serial_reference(&p, &h, workers, versions, n);
        let mut got_p = vec![0.0_f32; n];
        let mut got_m = vec![0.0_f32; n];
        for shard in 0..p.num_shards() {
            let (sp, sm) = hub.pull_final(shard, versions - 1).unwrap();
            p.scatter(shard, &sp, &mut got_p);
            p.scatter(shard, &sm, &mut got_m);
        }
        assert_eq!(got_p, want_p, "hub must match the serial reference bitwise");
        assert_eq!(got_m, want_m);
    }

    #[test]
    fn k0_gate_is_fully_synchronous() {
        // With K=0 every granted pull has applied == version: the exact
        // barrier semantics of synchronous SGD.
        let (n, workers, versions) = (32, 2, 12_u64);
        let p = plan(n, 8, &[0], 1);
        let init = vec![0.5_f32; n];
        let zeros = vec![0.0_f32; n];
        let hub = PsHub::new(p.clone(), hyper(0), workers, &init, &zeros);
        std::thread::scope(|s| {
            for w in 0..workers {
                let hub = &hub;
                let p = &p;
                s.spawn(move || {
                    for v in 0..versions {
                        if w == 1 && v % 3 == 0 {
                            std::thread::sleep(Duration::from_micros(200));
                        }
                        let g = grad(w, v, n);
                        hub.push(0, w, v, p.gather(0, &g)).unwrap();
                        let (_, stats) = hub.pull(0, v).unwrap();
                        assert_eq!(stats.applied, v as i64, "K=0 must be synchronous");
                        assert_eq!(stats.lag, 0);
                    }
                });
            }
        });
    }

    #[test]
    fn push_rejects_version_gaps() {
        let p = plan(8, 8, &[0], 1);
        let hub = PsHub::new(p, hyper(4), 1, &[0.0; 8], &[0.0; 8]);
        hub.push(0, 0, 0, vec![0.0; 8]).unwrap();
        // Skipping version 1 is a protocol violation.
        assert!(hub.push(0, 0, 2, vec![0.0; 8]).is_err());
        // Wrong payload size too.
        assert!(hub.push(0, 0, 1, vec![0.0; 3]).is_err());
    }

    #[test]
    fn load_window_tracks_push_rates() {
        let p = plan(16, 8, &[0], 2);
        let hub = PsHub::new(p, hyper(1), 2, &[0.0; 16], &[0.0; 16]);
        // Worker 0 pushes 4 versions (x2 shards), worker 1 pushes 1.
        for v in 0..4 {
            for shard in 0..2 {
                hub.push(shard, 0, v, vec![0.0; 8]).unwrap();
            }
        }
        for shard in 0..2 {
            hub.push(shard, 1, 0, vec![0.0; 8]).unwrap();
        }
        std::thread::sleep(Duration::from_millis(2));
        let w = hub.load_window(&[10, 10]);
        let (a, b) = (w[0].unwrap(), w[1].unwrap());
        assert!(a > 0.0 && b > 0.0);
        assert!(b > a, "fewer pushes must read as slower per-sample time");
        // Drained window with no new pushes -> None (no signal).
        assert_eq!(hub.load_window(&[10, 10]), vec![None, None]);
        // Zero allocation -> None even with pushes.
        hub.push(0, 1, 1, vec![0.0; 8]).unwrap();
        assert_eq!(hub.load_window(&[10, 0])[1], None);
    }

    // --- satellite: env-knob validation matches the house convention --

    #[test]
    fn staleness_knob_parses_and_rejects_garbage() {
        assert_eq!(
            parse_or_warn::<usize>(ENV_STALENESS, None, DEFAULT_STALENESS),
            DEFAULT_STALENESS
        );
        assert_eq!(parse_or_warn::<usize>(ENV_STALENESS, Some("4"), 1), 4);
        assert_eq!(parse_or_warn::<usize>(ENV_STALENESS, Some(" 0 "), 1), 0);
        for bad in ["-1", "1.5", "fast", ""] {
            assert_eq!(
                parse_or_warn::<usize>(ENV_STALENESS, Some(bad), 1),
                1,
                "{bad:?} must fall back to the default"
            );
        }
    }

    #[test]
    fn ps_shards_knob_parses_and_rejects_garbage() {
        assert_eq!(parse_or_warn::<usize>(ENV_PS_SHARDS, None, 0), 0);
        assert_eq!(parse_or_warn::<usize>(ENV_PS_SHARDS, Some("3"), 0), 3);
        for bad in ["two", "-2", "1e3"] {
            assert_eq!(
                parse_or_warn::<usize>(ENV_PS_SHARDS, Some(bad), 0),
                0,
                "{bad:?} must fall back to the default"
            );
        }
    }
}
