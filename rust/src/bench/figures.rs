//! Paper-figure harness: regenerates every table/figure of the paper's
//! evaluation (Section IV-D) from the calibrated model and, where
//! requested, from real shortened training runs.
//!
//! * [`fig2`] — Training Efficiency: total time for the six cluster
//!   configurations (virtual-time; accuracy from real runs is produced by
//!   `examples/accuracy_parity.rs`).
//! * [`fig3`] — Load-Adaptive Mechanism: strategies A (equal), B
//!   (KAITIAN adaptive), C (fixed wrong-way) on 1G+1M.
//! * [`fig4`] — Communication Overhead: native vs KAITIAN-managed
//!   homogeneous clusters (the "KAITIAN tax").
//! * [`microbench_collectives`] — real measured all-reduce latency vs
//!   message size on the vendor (in-proc) vs host-relay (TCP) paths.

use std::sync::Arc;

use crate::backend::{CollectiveBackend, GlooHostRelay, VendorKind, VendorSim};
use crate::collectives::{Communicator, ReduceOp};
use crate::group::GroupMode;
use crate::metrics::MarkdownTable;
use crate::perfmodel::PerfModel;
use crate::sched::Strategy;
use crate::simnet::{simulate, SimConfig};
use crate::transport::{InprocMesh, TcpMesh, Transport};
use crate::util::json::Json;
use crate::Result;

/// One regenerated figure: human table + machine-readable JSON.
pub struct FigureReport {
    pub id: &'static str,
    pub title: &'static str,
    pub table: String,
    pub json: Json,
}

impl FigureReport {
    pub fn render(&self) -> String {
        format!("## {} — {}\n\n{}", self.id, self.title, self.table)
    }
}

/// Paper's Fig-2 anchor numbers (seconds; None where the paper's text
/// doesn't give the exact value).
pub const FIG2_PAPER: [(&str, Option<f64>); 6] = [
    ("2G", Some(236.4)),
    ("2M", Some(166.3)),
    ("1G+1M", None),
    ("2G+1M", Some(175.0)),
    ("1G+2M", None),
    ("2G+2M", Some(137.4)),
];

/// Fig 2: training time across cluster configurations.
pub fn fig2(model: &PerfModel, grad_bytes: usize) -> Result<FigureReport> {
    let mut table = MarkdownTable::new(&[
        "config",
        "mode",
        "paper (s)",
        "model (s)",
        "Δ vs paper",
        "speedup vs 2G",
        "alloc (B=256)",
    ]);
    let mut rows = Vec::new();
    let t_2g_ref = simulate(
        model,
        &SimConfig::paper_workload("2G", GroupMode::Native, grad_bytes),
    )?
    .total_s;

    for (spec, paper) in FIG2_PAPER {
        let mode = if spec.contains('+') {
            GroupMode::Kaitian
        } else {
            GroupMode::Native
        };
        let r = simulate(model, &SimConfig::paper_workload(spec, mode, grad_bytes))?;
        let delta = paper
            .map(|p| format!("{:+.1}%", (r.total_s - p) / p * 100.0))
            .unwrap_or_else(|| "-".into());
        table.row(vec![
            spec.into(),
            format!("{mode:?}").to_lowercase(),
            paper.map(|p| format!("{p:.1}")).unwrap_or_else(|| "-".into()),
            format!("{:.1}", r.total_s),
            delta,
            format!("{:.0}%", (1.0 - r.total_s / t_2g_ref) * 100.0),
            format!("{:?}", r.allocation),
        ]);
        rows.push(Json::obj(vec![
            ("config", Json::str(spec)),
            ("paper_s", paper.map(Json::num).unwrap_or(Json::Null)),
            ("model_s", Json::num(r.total_s)),
            (
                "alloc",
                Json::arr(r.allocation.iter().map(|a| Json::num(*a as f64)).collect()),
            ),
            ("utilization", Json::num(r.utilization)),
            ("throughput_sps", Json::num(r.throughput)),
        ]));
    }
    Ok(FigureReport {
        id: "fig2",
        title: "Training efficiency across cluster configurations (50 epochs)",
        table: table.render(),
        json: Json::arr(rows),
    })
}

/// Fig 3: impact of the load-adaptive mechanism on 1G+1M.
pub fn fig3(model: &PerfModel, grad_bytes: usize) -> Result<FigureReport> {
    let strategies: [(&str, Strategy); 3] = [
        ("A: equal 50/50", Strategy::Equal),
        ("B: KAITIAN adaptive", Strategy::Adaptive),
        ("C: fixed 70/30 (wrong way)", Strategy::Fixed(vec![0.7, 0.3])),
    ];
    let mut table = MarkdownTable::new(&[
        "strategy",
        "alloc (B=256)",
        "step (ms)",
        "epoch (s)",
        "total 50 ep (s)",
        "compute util",
    ]);
    let mut rows = Vec::new();
    for (label, strategy) in strategies {
        let mut cfg = SimConfig::paper_workload("1G+1M", GroupMode::Kaitian, grad_bytes);
        cfg.strategy = strategy;
        let r = simulate(model, &cfg)?;
        table.row(vec![
            label.into(),
            format!("{:?}", r.allocation),
            format!("{:.2}", r.step.total() * 1e3),
            format!("{:.2}", r.step.total() * cfg.steps_per_epoch as f64),
            format!("{:.1}", r.total_s),
            format!("{:.0}%", r.utilization * 100.0),
        ]);
        rows.push(Json::obj(vec![
            ("strategy", Json::str(label)),
            ("total_s", Json::num(r.total_s)),
            ("utilization", Json::num(r.utilization)),
            (
                "alloc",
                Json::arr(r.allocation.iter().map(|a| Json::num(*a as f64)).collect()),
            ),
        ]));
    }
    Ok(FigureReport {
        id: "fig3",
        title: "Load-adaptive mechanism on 1G+1M (strategy A/B/C)",
        table: table.render(),
        json: Json::arr(rows),
    })
}

/// Fig-4 paper anchors: (config, native s, kaitian s).
pub const FIG4_PAPER: [(&str, f64, f64); 2] = [("2G", 226.1, 232.4), ("2M", 154.6, 161.3)];

/// Fig 4: KAITIAN framework overhead on homogeneous clusters.
pub fn fig4(model: &PerfModel, grad_bytes: usize) -> Result<FigureReport> {
    let mut table = MarkdownTable::new(&[
        "config",
        "native model (s)",
        "kaitian model (s)",
        "model overhead",
        "paper overhead",
    ]);
    let mut rows = Vec::new();
    for (spec, paper_native, paper_kaitian) in FIG4_PAPER {
        let native = simulate(
            model,
            &SimConfig::paper_workload(spec, GroupMode::Native, grad_bytes),
        )?;
        let kaitian = simulate(
            model,
            &SimConfig::paper_workload(spec, GroupMode::Kaitian, grad_bytes),
        )?;
        let overhead = (kaitian.total_s - native.total_s) / native.total_s;
        let paper_overhead = (paper_kaitian - paper_native) / paper_native;
        table.row(vec![
            spec.into(),
            format!("{:.1}", native.total_s),
            format!("{:.1}", kaitian.total_s),
            format!("{:.1}%", overhead * 100.0),
            format!("{:.1}%", paper_overhead * 100.0),
        ]);
        rows.push(Json::obj(vec![
            ("config", Json::str(spec)),
            ("native_s", Json::num(native.total_s)),
            ("kaitian_s", Json::num(kaitian.total_s)),
            ("overhead", Json::num(overhead)),
            ("paper_overhead", Json::num(paper_overhead)),
        ]));
    }
    Ok(FigureReport {
        id: "fig4",
        title: "KAITIAN overhead in homogeneous settings (native vs managed)",
        table: table.render(),
        json: Json::arr(rows),
    })
}

/// Real measured all-reduce latency vs message size: vendor (in-proc)
/// path vs host-relay (real TCP loopback) path.
pub fn microbench_collectives(world: usize, quick: bool) -> Result<FigureReport> {
    use super::runner::BenchRunner;
    let runner = if quick {
        BenchRunner::quick()
    } else {
        BenchRunner::default()
    };
    let sizes: &[usize] = if quick {
        &[1 << 10, 1 << 16, 1 << 20]
    } else {
        &[1 << 10, 1 << 14, 1 << 16, 1 << 18, 1 << 20, 1 << 22, 1 << 24]
    };

    let mut table = MarkdownTable::new(&[
        "bytes",
        "vendor-ring (in-proc)",
        "host-relay (tcp)",
        "relay/vendor",
    ]);
    let mut rows = Vec::new();

    for &bytes in sizes {
        let n = bytes / 4;
        let vendor_t = bench_all_reduce(
            &runner,
            InprocMesh::new(world)
                .into_iter()
                .map(|e| {
                    Box::new(VendorSim::new(
                        VendorKind::Nccl,
                        Communicator::new(Arc::new(e) as Arc<dyn Transport>),
                    )) as Box<dyn CollectiveBackend>
                })
                .collect(),
            n,
        );
        let relay_t = bench_all_reduce(
            &runner,
            TcpMesh::loopback(world)?
                .into_iter()
                .map(|e| {
                    Box::new(GlooHostRelay::new(Communicator::new(
                        Arc::new(e) as Arc<dyn Transport>
                    ))) as Box<dyn CollectiveBackend>
                })
                .collect(),
            n,
        );
        table.row(vec![
            crate::util::fmt_bytes(bytes),
            crate::util::fmt_secs(vendor_t),
            crate::util::fmt_secs(relay_t),
            format!("{:.1}x", relay_t / vendor_t.max(1e-9)),
        ]);
        rows.push(Json::obj(vec![
            ("bytes", Json::num(bytes as f64)),
            ("vendor_s", Json::num(vendor_t)),
            ("relay_s", Json::num(relay_t)),
        ]));
    }
    Ok(FigureReport {
        id: "microbench",
        title: "Measured all-reduce: vendor path vs host relay",
        table: table.render(),
        json: Json::arr(rows),
    })
}

/// Mean steady-state time of one all-reduce across `world` *persistent*
/// worker threads (perf-pass P3: spawning threads per iteration measured
/// scope/spawn overhead — hundreds of µs — instead of the collective; the
/// collective itself synchronizes ranks, so rank 0's loop time is the
/// step time).
fn bench_all_reduce(
    runner: &super::runner::BenchRunner,
    backends: Vec<Box<dyn CollectiveBackend>>,
    elems: usize,
) -> f64 {
    let warmup = runner.warmup.max(1);
    let iters = runner.iters.max(3);
    let results: Vec<f64> = std::thread::scope(|s| {
        let hs: Vec<_> = backends
            .iter()
            .map(|b| {
                s.spawn(move || {
                    let mut buf = vec![1.0_f32; elems];
                    for _ in 0..warmup {
                        b.all_reduce(&mut buf, ReduceOp::Sum).unwrap();
                    }
                    let t0 = std::time::Instant::now();
                    for _ in 0..iters {
                        b.all_reduce(&mut buf, ReduceOp::Sum).unwrap();
                    }
                    t0.elapsed().as_secs_f64() / iters as f64
                })
            })
            .collect();
        hs.into_iter().map(|h| h.join().unwrap()).collect()
    });
    // Ranks are lock-stepped by the collective; take the max (straggler).
    results.into_iter().fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    const GRAD_BYTES: usize = 933_544;

    #[test]
    fn fig2_report_contains_all_configs() {
        let r = fig2(&PerfModel::paper_default(), GRAD_BYTES).unwrap();
        for (spec, _) in FIG2_PAPER {
            assert!(r.table.contains(spec), "missing {spec}");
        }
        assert_eq!(r.json.as_arr().unwrap().len(), 6);
    }

    #[test]
    fn fig2_matches_paper_within_5pct() {
        let r = fig2(&PerfModel::paper_default(), GRAD_BYTES).unwrap();
        for row in r.json.as_arr().unwrap() {
            if let Some(paper) = row.req("paper_s").unwrap().as_f64() {
                let model = row.f64_req("model_s").unwrap();
                assert!(
                    ((model - paper) / paper).abs() < 0.05,
                    "{}: model {model:.1} vs paper {paper:.1}",
                    row.str_req("config").unwrap()
                );
            }
        }
    }

    #[test]
    fn fig3_b_wins() {
        let r = fig3(&PerfModel::paper_default(), GRAD_BYTES).unwrap();
        let rows = r.json.as_arr().unwrap();
        let total =
            |i: usize| rows[i].f64_req("total_s").unwrap();
        assert!(total(1) < total(0), "B must beat A");
        assert!(total(0) < total(2), "A must beat C");
    }

    #[test]
    fn fig4_overheads_in_paper_band() {
        let r = fig4(&PerfModel::paper_default(), GRAD_BYTES).unwrap();
        for row in r.json.as_arr().unwrap() {
            let o = row.f64_req("overhead").unwrap();
            assert!((0.02..0.055).contains(&o), "overhead {o}");
        }
    }

    #[test]
    fn microbench_runs_quick() {
        let r = microbench_collectives(2, true).unwrap();
        assert!(r.table.contains("KiB") || r.table.contains("MiB"));
        assert_eq!(r.json.as_arr().unwrap().len(), 3);
    }
}
