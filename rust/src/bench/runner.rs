//! Mini-criterion: warmup + timed iterations, robust summary statistics.

use std::time::Instant;

/// Summary of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchStat {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub std_s: f64,
    pub min_s: f64,
    pub p50_s: f64,
    pub max_s: f64,
}

impl BenchStat {
    pub fn line(&self) -> String {
        format!(
            "{:40} {:>12} {:>12} {:>12} ({} iters)",
            self.name,
            crate::util::fmt_secs(self.p50_s),
            format!("±{}", crate::util::fmt_secs(self.std_s)),
            format!("min {}", crate::util::fmt_secs(self.min_s)),
            self.iters
        )
    }
}

/// Timing harness with fixed warmup/iteration counts.
#[derive(Debug, Clone, Copy)]
pub struct BenchRunner {
    pub warmup: usize,
    pub iters: usize,
}

impl Default for BenchRunner {
    fn default() -> Self {
        Self {
            warmup: 3,
            iters: 15,
        }
    }
}

impl BenchRunner {
    pub fn quick() -> Self {
        Self { warmup: 1, iters: 5 }
    }

    /// Time `f` (called once per iteration).
    pub fn bench<T>(&self, name: &str, mut f: impl FnMut() -> T) -> BenchStat {
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        let mut times = Vec::with_capacity(self.iters);
        for _ in 0..self.iters {
            let t0 = Instant::now();
            std::hint::black_box(f());
            times.push(t0.elapsed().as_secs_f64());
        }
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = times.iter().sum::<f64>() / times.len() as f64;
        let var = times.iter().map(|t| (t - mean).powi(2)).sum::<f64>() / times.len() as f64;
        BenchStat {
            name: name.to_string(),
            iters: self.iters,
            mean_s: mean,
            std_s: var.sqrt(),
            min_s: times[0],
            p50_s: times[times.len() / 2],
            max_s: *times.last().unwrap(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_are_sane() {
        let r = BenchRunner { warmup: 1, iters: 9 };
        let stat = r.bench("spin", || {
            let mut x = 0_u64;
            for i in 0..10_000 {
                x = x.wrapping_add(i);
            }
            x
        });
        assert_eq!(stat.iters, 9);
        assert!(stat.min_s <= stat.p50_s && stat.p50_s <= stat.max_s);
        assert!(stat.mean_s > 0.0);
        assert!(!stat.line().is_empty());
    }
}
