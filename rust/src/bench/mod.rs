//! Benchmark infrastructure: a mini-criterion timing harness (the
//! vendored crate set has no `criterion`) plus the paper-figure harness
//! shared by `cargo bench` targets and `kaitian bench`.

pub mod figures;
pub mod runner;

pub use figures::{fig2, fig3, fig4, microbench_collectives, FigureReport};
pub use runner::{BenchRunner, BenchStat};
