//! Tag-matched message buffer shared by all transports.
//!
//! Incoming messages are parked under `(peer, tag)`; `recv` blocks until
//! a matching message arrives. This decouples send and recv ordering —
//! exactly what collective algorithms need when every rank is
//! simultaneously sending and receiving.
//!
//! Rebuilt for the zero-copy data plane: messages are [`Buf`]s (parking
//! one is a refcount move, not a copy), and the single global
//! `Mutex<HashMap>` + `notify_all` of the old design is replaced by
//! sharded slot tables with one condvar *per (peer, tag) slot* — a push
//! wakes only receivers of that slot, and concurrent (peer, tag) flows
//! touch different locks. Slots are removed when drained (under the
//! shard lock, so a racing push can never strand a message in an
//! orphaned slot).

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::bail;

use crate::comm::buf::Buf;
use crate::Result;

/// Default receive timeout: long enough for slow CI machines, short
/// enough to turn a deadlock into a diagnosable error. Overridable via
/// `KAITIAN_RECV_TIMEOUT_MS` (failure-injection tests use short values).
pub const RECV_TIMEOUT: Duration = Duration::from_secs(60);

/// The effective receive timeout (env override or [`RECV_TIMEOUT`]).
pub fn recv_timeout() -> Duration {
    static CACHED: std::sync::OnceLock<Duration> = std::sync::OnceLock::new();
    *CACHED.get_or_init(|| {
        std::env::var("KAITIAN_RECV_TIMEOUT_MS")
            .ok()
            .and_then(|v| v.parse::<u64>().ok())
            .map(Duration::from_millis)
            .unwrap_or(RECV_TIMEOUT)
    })
}

/// Shard count: (peer, tag) flows spread across this many slot tables.
const SHARDS: usize = 16;

struct SlotState {
    queue: VecDeque<Buf>,
    closed: bool,
}

/// One (peer, tag) flow: its queue plus a dedicated condvar so a push
/// wakes only the receivers actually waiting for this flow.
struct Slot {
    state: Mutex<SlotState>,
    cv: Condvar,
}

impl Slot {
    fn new(closed: bool) -> Self {
        Self {
            state: Mutex::new(SlotState {
                queue: VecDeque::new(),
                closed,
            }),
            cv: Condvar::new(),
        }
    }
}

#[derive(Default)]
struct Shard {
    slots: Mutex<HashMap<(usize, u64), Arc<Slot>>>,
}

/// One rank's incoming-message buffer.
pub struct Mailbox {
    shards: Vec<Shard>,
    closed: AtomicBool,
}

impl Default for Mailbox {
    fn default() -> Self {
        Self::new()
    }
}

#[inline]
fn shard_of(peer: usize, tag: u64) -> usize {
    // Cheap avalanche over both keys; tags differ in high bits (op
    // counter) and low bits (chunk index), so multiply-fold both.
    let h = (peer as u64)
        .wrapping_mul(0x9E37_79B9_7F4A_7C15)
        .wrapping_add(tag.wrapping_mul(0xD1B5_4A32_D192_ED03));
    ((h >> 57) as usize) % SHARDS
}

impl Mailbox {
    pub fn new() -> Self {
        Self {
            shards: (0..SHARDS).map(|_| Shard::default()).collect(),
            closed: AtomicBool::new(false),
        }
    }

    /// Get-or-create the slot for `(peer, tag)`.
    fn slot(&self, peer: usize, tag: u64) -> Arc<Slot> {
        let shard = &self.shards[shard_of(peer, tag)];
        let mut slots = shard.slots.lock().unwrap();
        slots
            .entry((peer, tag))
            .or_insert_with(|| Arc::new(Slot::new(self.closed.load(Ordering::SeqCst))))
            .clone()
    }

    /// Deliver a message from `peer` under `tag` (refcount move, no
    /// copy). Wakes one receiver of exactly this flow.
    pub fn push(&self, peer: usize, tag: u64, data: Buf) {
        let shard = &self.shards[shard_of(peer, tag)];
        let mut slots = shard.slots.lock().unwrap();
        let slot = slots
            .entry((peer, tag))
            .or_insert_with(|| Arc::new(Slot::new(self.closed.load(Ordering::SeqCst))))
            .clone();
        // Push while still holding the shard lock: a concurrent `pop`
        // that drained the slot removes it only under this lock, so the
        // slot we just looked up is guaranteed to still be the live one.
        let mut st = slot.state.lock().unwrap();
        st.queue.push_back(data);
        drop(st);
        drop(slots);
        slot.cv.notify_one();
    }

    /// Blocking, tag-matched receive with timeout.
    ///
    /// Perf-pass P4 (kept from the pre-shard design): collective ring
    /// steps are latency-bound for small messages, and a condvar
    /// sleep/wake costs ~10–20 µs per hop, so we spin briefly on the
    /// slot before parking.
    pub fn pop(&self, peer: usize, tag: u64, timeout: Duration) -> Result<Buf> {
        let slot = self.slot(peer, tag);

        const SPIN_BUDGET: Duration = Duration::from_micros(40);
        let spin_start = Instant::now();
        while spin_start.elapsed() < SPIN_BUDGET {
            {
                let mut st = slot.state.lock().unwrap();
                if let Some(msg) = st.queue.pop_front() {
                    let drained = st.queue.is_empty();
                    drop(st);
                    if drained {
                        self.try_remove(peer, tag, &slot);
                    }
                    return Ok(msg);
                }
                if st.closed {
                    bail!("mailbox closed while waiting for (peer={peer}, tag={tag})");
                }
            }
            std::hint::spin_loop();
        }

        let deadline = Instant::now() + timeout;
        let mut st = slot.state.lock().unwrap();
        loop {
            if let Some(msg) = st.queue.pop_front() {
                let drained = st.queue.is_empty();
                drop(st);
                if drained {
                    self.try_remove(peer, tag, &slot);
                }
                return Ok(msg);
            }
            if st.closed {
                bail!("mailbox closed while waiting for (peer={peer}, tag={tag})");
            }
            let now = Instant::now();
            if now >= deadline {
                bail!(
                    "recv timeout waiting for (peer={peer}, tag={tag}) — \
                     likely a collective deadlock or a dead peer"
                );
            }
            let (guard, _res) = slot.cv.wait_timeout(st, deadline - now).unwrap();
            st = guard;
        }
    }

    /// Drop the slot from its shard if it is still drained and idle
    /// (keeps long-running communicators from accumulating one empty
    /// slot per retired tag). `ours` is the popper's own reference; a
    /// slot is idle when the map holds the only *other* reference — any
    /// concurrent waiter or pusher holds its own clone and keeps the
    /// slot alive.
    fn try_remove(&self, peer: usize, tag: u64, ours: &Arc<Slot>) {
        let shard = &self.shards[shard_of(peer, tag)];
        let mut slots = shard.slots.lock().unwrap();
        let removable = match slots.get(&(peer, tag)) {
            Some(current) => {
                Arc::ptr_eq(current, ours)            // not replaced by a newer slot
                    && Arc::strong_count(current) <= 2 // map + ours, no waiter/pusher
                    && current.state.lock().unwrap().queue.is_empty() // not refilled
            }
            None => false,
        };
        if removable {
            slots.remove(&(peer, tag));
        }
    }

    /// Wake all blocked receivers with an error (mesh shutdown).
    pub fn close(&self) {
        self.closed.store(true, Ordering::SeqCst);
        for shard in &self.shards {
            let slots = shard.slots.lock().unwrap();
            for slot in slots.values() {
                slot.state.lock().unwrap().closed = true;
                slot.cv.notify_all();
            }
        }
    }

    /// Number of queued (undelivered) messages — for tests/diagnostics.
    pub fn pending(&self) -> usize {
        self.shards
            .iter()
            .map(|shard| {
                shard
                    .slots
                    .lock()
                    .unwrap()
                    .values()
                    .map(|slot| slot.state.lock().unwrap().queue.len())
                    .sum::<usize>()
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn buf(bytes: &[u8]) -> Buf {
        Buf::copy_from_slice(bytes)
    }

    #[test]
    fn push_pop_fifo_per_tag() {
        let mb = Mailbox::new();
        mb.push(0, 7, buf(&[1]));
        mb.push(0, 7, buf(&[2]));
        mb.push(0, 9, buf(&[3]));
        assert_eq!(mb.pop(0, 7, RECV_TIMEOUT).unwrap(), vec![1]);
        assert_eq!(mb.pop(0, 9, RECV_TIMEOUT).unwrap(), vec![3]);
        assert_eq!(mb.pop(0, 7, RECV_TIMEOUT).unwrap(), vec![2]);
        assert_eq!(mb.pending(), 0, "drained slots are removed");
    }

    #[test]
    fn tags_do_not_cross_match() {
        let mb = Mailbox::new();
        mb.push(1, 5, buf(&[42]));
        assert!(mb.pop(1, 6, Duration::from_millis(50)).is_err());
        assert_eq!(mb.pop(1, 5, RECV_TIMEOUT).unwrap(), vec![42]);
    }

    #[test]
    fn blocking_pop_wakes_on_push() {
        let mb = Arc::new(Mailbox::new());
        let mb2 = mb.clone();
        let h = std::thread::spawn(move || mb2.pop(3, 1, RECV_TIMEOUT).unwrap());
        std::thread::sleep(Duration::from_millis(20));
        mb.push(3, 1, buf(&[9, 9]));
        assert_eq!(h.join().unwrap(), vec![9, 9]);
    }

    #[test]
    fn timeout_is_an_error() {
        let mb = Mailbox::new();
        let err = mb.pop(0, 0, Duration::from_millis(30)).unwrap_err();
        assert!(err.to_string().contains("timeout"));
    }

    #[test]
    fn close_unblocks_receivers() {
        let mb = Arc::new(Mailbox::new());
        let mb2 = mb.clone();
        let h = std::thread::spawn(move || mb2.pop(0, 0, Duration::from_secs(30)));
        std::thread::sleep(Duration::from_millis(20));
        mb.close();
        assert!(h.join().unwrap().is_err());
    }

    #[test]
    fn close_then_pop_errors_without_waiting() {
        let mb = Mailbox::new();
        mb.close();
        let t0 = Instant::now();
        assert!(mb.pop(0, 1, Duration::from_secs(30)).is_err());
        assert!(t0.elapsed() < Duration::from_secs(1));
    }

    #[test]
    fn zero_length_messages_deliver() {
        let mb = Mailbox::new();
        mb.push(2, 4, Buf::empty());
        assert!(mb.pop(2, 4, RECV_TIMEOUT).unwrap().is_empty());
    }

    #[test]
    fn concurrent_flows_do_not_interfere() {
        let mb = Arc::new(Mailbox::new());
        std::thread::scope(|s| {
            for tag in 0..8_u64 {
                let mb = mb.clone();
                s.spawn(move || {
                    for i in 0..50_u8 {
                        mb.push(tag as usize, tag, buf(&[i]));
                    }
                });
                let mb = mb.clone();
                s.spawn(move || {
                    for i in 0..50_u8 {
                        let got = mb.pop(tag as usize, tag, RECV_TIMEOUT).unwrap();
                        assert_eq!(got, vec![i], "per-flow FIFO must hold");
                    }
                });
            }
        });
        assert_eq!(mb.pending(), 0);
    }
}
