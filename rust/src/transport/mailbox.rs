//! Tag-matched message buffer shared by all transports.
//!
//! Incoming messages are queued under `(peer, tag)`; `recv` blocks on a
//! condvar until a matching message arrives. This decouples send and recv
//! ordering — exactly what collective algorithms need when every rank is
//! simultaneously sending and receiving.

use std::collections::{HashMap, VecDeque};
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::bail;

use crate::Result;

/// Default receive timeout: long enough for slow CI machines, short
/// enough to turn a deadlock into a diagnosable error. Overridable via
/// `KAITIAN_RECV_TIMEOUT_MS` (failure-injection tests use short values).
pub const RECV_TIMEOUT: Duration = Duration::from_secs(60);

/// The effective receive timeout (env override or [`RECV_TIMEOUT`]).
pub fn recv_timeout() -> Duration {
    static CACHED: std::sync::OnceLock<Duration> = std::sync::OnceLock::new();
    *CACHED.get_or_init(|| {
        std::env::var("KAITIAN_RECV_TIMEOUT_MS")
            .ok()
            .and_then(|v| v.parse::<u64>().ok())
            .map(Duration::from_millis)
            .unwrap_or(RECV_TIMEOUT)
    })
}

#[derive(Default)]
struct Inner {
    queues: HashMap<(usize, u64), VecDeque<Vec<u8>>>,
    /// Set when the mesh is shutting down; wakes blocked receivers.
    closed: bool,
}

/// One rank's incoming-message buffer.
#[derive(Default)]
pub struct Mailbox {
    inner: Mutex<Inner>,
    cv: Condvar,
}

impl Mailbox {
    pub fn new() -> Self {
        Self::default()
    }

    /// Deliver a message from `peer` under `tag`.
    pub fn push(&self, peer: usize, tag: u64, data: Vec<u8>) {
        let mut inner = self.inner.lock().unwrap();
        inner.queues.entry((peer, tag)).or_default().push_back(data);
        self.cv.notify_all();
    }

    /// Blocking, tag-matched receive with timeout.
    ///
    /// Perf-pass P4: collective ring steps are latency-bound for small
    /// messages, and a condvar sleep/wake costs ~10–20 µs per hop. We
    /// first spin briefly (re-checking the queue) before parking — the
    /// expected inter-arrival gap during an in-flight collective is well
    /// under the spin budget.
    pub fn pop(&self, peer: usize, tag: u64, timeout: Duration) -> Result<Vec<u8>> {
        const SPIN_BUDGET: Duration = Duration::from_micros(40);
        let spin_start = Instant::now();
        while spin_start.elapsed() < SPIN_BUDGET {
            {
                let mut inner = self.inner.lock().unwrap();
                if let Some(q) = inner.queues.get_mut(&(peer, tag)) {
                    if let Some(msg) = q.pop_front() {
                        return Ok(msg);
                    }
                }
                if inner.closed {
                    anyhow::bail!("mailbox closed while waiting for (peer={peer}, tag={tag})");
                }
            }
            std::hint::spin_loop();
        }

        let deadline = Instant::now() + timeout;
        let mut inner = self.inner.lock().unwrap();
        loop {
            if let Some(q) = inner.queues.get_mut(&(peer, tag)) {
                if let Some(msg) = q.pop_front() {
                    return Ok(msg);
                }
            }
            if inner.closed {
                bail!("mailbox closed while waiting for (peer={peer}, tag={tag})");
            }
            let now = Instant::now();
            if now >= deadline {
                bail!(
                    "recv timeout waiting for (peer={peer}, tag={tag}) — \
                     likely a collective deadlock or a dead peer"
                );
            }
            let (guard, res) = self
                .cv
                .wait_timeout(inner, deadline - now)
                .unwrap();
            inner = guard;
            if res.timed_out() {
                // loop once more to re-check queue then fail
            }
        }
    }

    /// Wake all blocked receivers with an error (mesh shutdown).
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.cv.notify_all();
    }

    /// Number of queued (undelivered) messages — for tests/diagnostics.
    pub fn pending(&self) -> usize {
        self.inner
            .lock()
            .unwrap()
            .queues
            .values()
            .map(|q| q.len())
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn push_pop_fifo_per_tag() {
        let mb = Mailbox::new();
        mb.push(0, 7, vec![1]);
        mb.push(0, 7, vec![2]);
        mb.push(0, 9, vec![3]);
        assert_eq!(mb.pop(0, 7, RECV_TIMEOUT).unwrap(), vec![1]);
        assert_eq!(mb.pop(0, 9, RECV_TIMEOUT).unwrap(), vec![3]);
        assert_eq!(mb.pop(0, 7, RECV_TIMEOUT).unwrap(), vec![2]);
    }

    #[test]
    fn tags_do_not_cross_match() {
        let mb = Mailbox::new();
        mb.push(1, 5, vec![42]);
        assert!(mb.pop(1, 6, Duration::from_millis(50)).is_err());
        assert_eq!(mb.pop(1, 5, RECV_TIMEOUT).unwrap(), vec![42]);
    }

    #[test]
    fn blocking_pop_wakes_on_push() {
        let mb = Arc::new(Mailbox::new());
        let mb2 = mb.clone();
        let h = std::thread::spawn(move || mb2.pop(3, 1, RECV_TIMEOUT).unwrap());
        std::thread::sleep(Duration::from_millis(20));
        mb.push(3, 1, vec![9, 9]);
        assert_eq!(h.join().unwrap(), vec![9, 9]);
    }

    #[test]
    fn timeout_is_an_error() {
        let mb = Mailbox::new();
        let err = mb.pop(0, 0, Duration::from_millis(30)).unwrap_err();
        assert!(err.to_string().contains("timeout"));
    }

    #[test]
    fn close_unblocks_receivers() {
        let mb = Arc::new(Mailbox::new());
        let mb2 = mb.clone();
        let h = std::thread::spawn(move || mb2.pop(0, 0, Duration::from_secs(30)));
        std::thread::sleep(Duration::from_millis(20));
        mb.close();
        assert!(h.join().unwrap().is_err());
    }
}
