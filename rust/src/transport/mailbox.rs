//! Tag-matched message buffer shared by all transports.
//!
//! Incoming messages are parked under `(peer, tag)`; `recv` blocks until
//! a matching message arrives. This decouples send and recv ordering —
//! exactly what collective algorithms need when every rank is
//! simultaneously sending and receiving.
//!
//! Rebuilt (ISSUE 6) on the lock-free slab primitives in
//! [`crate::comm::slab`]: the `Mutex<HashMap>` shard tables of the
//! previous design are replaced by open-addressed entry tables probed
//! with plain atomic loads, per-flow FIFO queues are lock-free MPMC
//! queues over a shared node arena, and flow slots live in a
//! generation-tagged arena so reclaimed slots are recycled (never
//! freed) and stale references are structurally detectable.
//!
//! Hot-path guarantees (asserted by `fast_path_takes_no_park_lock`):
//!
//! * `push` of an existing flow: lookup is a lock-free probe + one
//!   pin CAS; enqueue is the slab queue's two CASes. No mutex.
//! * `pop` with data present: same lookup; the spin phase reads only
//!   the flow's `pushed`/`popped` counters (satellite 1 — no contention
//!   with the pusher while waiting); dequeue is one CAS. No mutex.
//! * The per-flow parking `Mutex`/`Condvar` is touched only when a
//!   receiver actually parks, and a pusher signals it only when the
//!   `waiters` gauge says somebody is parked (the empty→nonempty edge
//!   discipline: steady-state traffic never signals).
//! * Flow *creation* (first message of a (peer, tag) stream) serializes
//!   on a tiny per-shard spin lock — get-or-create into an
//!   open-addressed table cannot be made duplicate-free lock-free
//!   without it, and it is off the steady-state path by definition.
//!
//! Entry life cycle: `EMPTY → FULL ⇄ REMOVING → TOMB → FULL → …`. A
//! pin (reference count in the entry's state word, version-protected
//! against recycling) keeps a flow alive while a push/pop uses it; the
//! popper that drains a flow while holding the only pin reclaims it
//! (queue torn down, slot retired, entry tombstoned). Tombstones never
//! revert to EMPTY, which keeps probe chains stable without locks;
//! inserts reuse them, so the table occupancy tracks *peak concurrent*
//! flows, not cumulative tag count.
//!
//! # Failure semantics (ISSUE 7)
//!
//! Two failure scopes, deliberately distinct:
//!
//! * [`Mailbox::close`] — the whole mailbox is going away (mesh
//!   shutdown or a collective abort). Every blocked receiver errors.
//! * [`Mailbox::close_peer`] — exactly one peer died. Only receivers
//!   waiting on that peer's flows error (with a distinct
//!   `"peer N lost"` message); traffic from every other peer keeps
//!   flowing. Messages the dead peer queued *before* dying remain
//!   deliverable, matching `close`'s drain-first contract.
//!
//! Epoch fencing: the membership layer bumps the mailbox epoch
//! ([`Mailbox::set_epoch`]) when the group re-forms after a failure.
//! [`Mailbox::push_epoch`] drops frames stamped with an older epoch at
//! the door (counted by [`Mailbox::stale_dropped`]) — a straggling
//! frame from a dead generation is never delivered into the new one.

use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail};

use crate::comm::buf::Buf;
use crate::comm::slab::{pack, ref_idx, Arena, Node, Queue};
use crate::Result;

/// Default receive timeout: long enough for slow CI machines, short
/// enough to turn a deadlock into a diagnosable error. Overridable via
/// `KAITIAN_RECV_TIMEOUT_MS` (failure-injection tests use short values).
pub const RECV_TIMEOUT: Duration = Duration::from_secs(60);

/// The effective receive timeout (env override or [`RECV_TIMEOUT`]).
pub fn recv_timeout() -> Duration {
    static CACHED: std::sync::OnceLock<Duration> = std::sync::OnceLock::new();
    *CACHED.get_or_init(|| {
        std::env::var("KAITIAN_RECV_TIMEOUT_MS")
            .ok()
            .and_then(|v| v.parse::<u64>().ok())
            .map(Duration::from_millis)
            .unwrap_or(RECV_TIMEOUT)
    })
}

/// Shard count: (peer, tag) flows spread across this many entry tables.
const SHARDS: usize = 16;
/// Words in the dead-peer bitmap: covers ranks 0..1024 with one atomic
/// load on the pop path. Worlds beyond that fall back to whole-mailbox
/// close on peer failure (see [`Mailbox::close_peer`]).
const DEAD_WORDS: usize = 16;
/// Entries per shard (power of two). Bounds *concurrent* flows per
/// shard; tombstoned entries are reused by later flows.
const FLOWS_PER_SHARD: usize = 2048;

// Entry state word layout: | version : 42 | pins : 20 | status : 2 |.
// Every transition bumps the version, so a CAS against a stale word
// fails even if status and pins look identical (ABA defense).
const STATUS_EMPTY: u64 = 0;
const STATUS_FULL: u64 = 1;
const STATUS_REMOVING: u64 = 2;
const STATUS_TOMB: u64 = 3;
const STATUS_MASK: u64 = 0b11;
const PIN_ONE: u64 = 1 << 2;
const PIN_MASK: u64 = ((1 << 20) - 1) << 2;
const VER_ONE: u64 = 1 << 22;
const VER_MASK: u64 = !(STATUS_MASK | PIN_MASK);

#[inline]
fn status(s: u64) -> u64 {
    s & STATUS_MASK
}

#[inline]
fn pin_count(s: u64) -> u64 {
    (s & PIN_MASK) >> 2
}

/// The successor state word: `from`'s version bumped, new status and
/// pin count installed.
#[inline]
fn next_ver(from: u64, st: u64, pins: u64) -> u64 {
    ((from & VER_MASK).wrapping_add(VER_ONE) & VER_MASK) | (pins << 2) | st
}

/// One cell of a shard's open-addressed flow table (32 bytes). The key
/// fields are rewritten only while the cell is EMPTY/TOMB under the
/// shard's creation lock; readers racing a rewrite are caught by the
/// version check in their pin CAS.
#[derive(Default)]
struct Entry {
    state: AtomicU64,
    peer: AtomicU64,
    tag: AtomicU64,
    /// Tagged reference ([`pack`]) to the flow's slot in the arena.
    slot: AtomicU64,
}

/// Tiny spin lock serializing flow *creation* within one shard (the
/// push/pop fast paths never touch it).
#[derive(Default)]
struct CreateLock(AtomicBool);

struct CreateGuard<'a>(&'a CreateLock);

impl CreateLock {
    fn lock(&self) -> CreateGuard<'_> {
        let mut spins = 0_u32;
        loop {
            if self
                .0
                .compare_exchange_weak(false, true, Ordering::Acquire, Ordering::Relaxed)
                .is_ok()
            {
                return CreateGuard(self);
            }
            spins = spins.wrapping_add(1);
            if spins % 64 == 0 {
                std::thread::yield_now();
            } else {
                std::hint::spin_loop();
            }
        }
    }
}

impl Drop for CreateGuard<'_> {
    fn drop(&mut self) {
        (self.0).0.store(false, Ordering::Release);
    }
}

struct Shard {
    entries: Box<[Entry]>,
    create: CreateLock,
}

/// One (peer, tag) flow: its lock-free FIFO plus the eventcount used
/// for spinning (counters only) and parking (mutex + condvar, slow
/// path only).
#[derive(Default)]
struct FlowSlot {
    q: Queue,
    /// Messages ever enqueued (bumped *after* the queue link).
    pushed: AtomicU64,
    /// Messages ever dequeued.
    popped: AtomicU64,
    /// Receivers currently parked (or about to park) on `cv`.
    waiters: AtomicU32,
    park: Mutex<()>,
    cv: Condvar,
}

/// A pinned flow entry: while held, the flow's slot cannot be
/// reclaimed. Dropped via [`Mailbox::unpin`].
struct Pinned<'a> {
    entry: &'a Entry,
    slot_idx: u32,
}

/// One rank's incoming-message buffer.
pub struct Mailbox {
    shards: Box<[Shard]>,
    slots: Arena<FlowSlot>,
    nodes: Arena<Node<Buf>>,
    /// Queued (undelivered) message gauge — bumped before the enqueue,
    /// decremented after a successful dequeue, so it never goes
    /// negative and is exact whenever the mailbox is quiescent.
    pending: AtomicU64,
    /// Parking-mutex acquisition counter (diagnostic): the only mutex
    /// in the mailbox, so fast-path tests can assert it stayed at zero.
    park_locks: AtomicU64,
    closed: AtomicBool,
    /// Dead-peer bitmap: bit `p` set means peer `p`'s flows fail with
    /// "peer p lost" instead of blocking. One relaxed-cost atomic load
    /// on the pop wait path; never consulted on the data-ready path.
    dead: [AtomicU64; DEAD_WORDS],
    /// Current membership epoch (monotonic). Frames stamped with an
    /// older epoch are refused by [`Mailbox::push_epoch`].
    epoch: AtomicU64,
    /// Frames dropped by epoch fencing — observability gauge.
    stale_dropped: AtomicU64,
}

impl Default for Mailbox {
    fn default() -> Self {
        Self::new()
    }
}

#[inline]
fn mix(peer: usize, tag: u64) -> u64 {
    // Cheap avalanche over both keys; tags differ in high bits (op
    // counter) and low bits (chunk index), so multiply-fold both.
    (peer as u64)
        .wrapping_mul(0x9E37_79B9_7F4A_7C15)
        .wrapping_add(tag.wrapping_mul(0xD1B5_4A32_D192_ED03))
}

impl Mailbox {
    pub fn new() -> Self {
        Self {
            shards: (0..SHARDS)
                .map(|_| Shard {
                    entries: (0..FLOWS_PER_SHARD).map(|_| Entry::default()).collect(),
                    create: CreateLock::default(),
                })
                .collect(),
            slots: Arena::new(),
            nodes: Arena::new(),
            pending: AtomicU64::new(0),
            park_locks: AtomicU64::new(0),
            closed: AtomicBool::new(false),
            dead: std::array::from_fn(|_| AtomicU64::new(0)),
            epoch: AtomicU64::new(0),
            stale_dropped: AtomicU64::new(0),
        }
    }

    /// Pin the flow entry for `(peer, tag)`, creating it if absent.
    fn pin(&self, peer: usize, tag: u64) -> Pinned<'_> {
        let h = mix(peer, tag);
        let shard = &self.shards[((h >> 57) as usize) % SHARDS];
        let start = ((h >> 41) as usize) & (FLOWS_PER_SHARD - 1);
        'restart: loop {
            // Lock-free probe: linear chain from `start`, terminated by
            // the first EMPTY cell (tombstones never revert to EMPTY,
            // so a chain observed mid-flight is still a valid chain).
            'probe: for i in 0..FLOWS_PER_SHARD {
                let e = &shard.entries[(start + i) & (FLOWS_PER_SHARD - 1)];
                let mut s = e.state.load(Ordering::Acquire);
                loop {
                    let st = status(s);
                    if st == STATUS_EMPTY {
                        break 'probe; // chain ends: key absent
                    }
                    if st == STATUS_TOMB {
                        break; // dead cell, keep probing
                    }
                    if e.peer.load(Ordering::Relaxed) != peer as u64
                        || e.tag.load(Ordering::Relaxed) != tag
                    {
                        break; // different flow, keep probing
                    }
                    if st == STATUS_REMOVING {
                        // Our flow is mid-reclamation: wait for it to
                        // settle to TOMB (gone — re-run the lookup) or
                        // back to FULL (rolled back — pin it).
                        std::hint::spin_loop();
                        let s2 = e.state.load(Ordering::Acquire);
                        if status(s2) == STATUS_TOMB {
                            continue 'restart;
                        }
                        s = s2;
                        continue;
                    }
                    // FULL and the key matched. The pin CAS re-validates
                    // the whole state word: if the cell was recycled to
                    // another flow after our key compare, the version
                    // moved and the CAS fails.
                    match e.state.compare_exchange_weak(
                        s,
                        s + PIN_ONE,
                        Ordering::AcqRel,
                        Ordering::Acquire,
                    ) {
                        Ok(_) => {
                            let idx = ref_idx(e.slot.load(Ordering::Acquire));
                            return Pinned { entry: e, slot_idx: idx };
                        }
                        Err(cur) => {
                            s = cur;
                        }
                    }
                }
            }

            // Slow path: create (or late-find) under the shard's
            // creation lock. Only flow creation serializes here — a
            // concurrent lock-free probe-and-pin of an existing flow
            // proceeds untouched.
            let _guard = shard.create.lock();
            let mut reuse: Option<usize> = None;
            let mut empty_at: Option<usize> = None;
            for i in 0..FLOWS_PER_SHARD {
                let ei = (start + i) & (FLOWS_PER_SHARD - 1);
                let e = &shard.entries[ei];
                let s = e.state.load(Ordering::Acquire);
                let st = status(s);
                if st == STATUS_EMPTY {
                    empty_at = Some(ei);
                    break;
                }
                if st == STATUS_TOMB {
                    if reuse.is_none() {
                        reuse = Some(ei);
                    }
                    continue;
                }
                // FULL or REMOVING: key fields are stable (rewrites
                // happen only under this creation lock).
                if e.peer.load(Ordering::Relaxed) != peer as u64
                    || e.tag.load(Ordering::Relaxed) != tag
                {
                    continue;
                }
                if st == STATUS_REMOVING {
                    continue 'restart; // let the reclaim settle, retry
                }
                let mut cur = s;
                loop {
                    if status(cur) != STATUS_FULL {
                        continue 'restart;
                    }
                    match e.state.compare_exchange_weak(
                        cur,
                        cur + PIN_ONE,
                        Ordering::AcqRel,
                        Ordering::Acquire,
                    ) {
                        Ok(_) => {
                            let idx = ref_idx(e.slot.load(Ordering::Acquire));
                            return Pinned { entry: e, slot_idx: idx };
                        }
                        Err(c) => cur = c,
                    }
                }
            }
            let Some(ei) = reuse.or(empty_at) else {
                panic!("mailbox shard out of flow entries ({FLOWS_PER_SHARD} concurrent flows)");
            };
            let e = &shard.entries[ei];
            let s = e.state.load(Ordering::Relaxed);
            debug_assert!(matches!(status(s), STATUS_EMPTY | STATUS_TOMB));
            e.peer.store(peer as u64, Ordering::Relaxed);
            e.tag.store(tag, Ordering::Relaxed);
            let sidx = self.slots.alloc();
            let slot = self.slots.slot(sidx);
            slot.item.q.init(&self.nodes);
            slot.item.pushed.store(0, Ordering::Relaxed);
            slot.item.popped.store(0, Ordering::Relaxed);
            e.slot.store(pack(slot.generation(), sidx), Ordering::Relaxed);
            // Publish FULL with our pin pre-counted; the version bump
            // defeats any CAS aimed at the cell's previous incarnation.
            e.state.store(next_ver(s, STATUS_FULL, 1), Ordering::Release);
            return Pinned { entry: e, slot_idx: sidx };
        }
    }

    /// Release a pin. With `try_reclaim`, a popper holding the *only*
    /// pin on a drained flow reclaims it: FULL→REMOVING shuts out new
    /// pins, a re-check of the (now final) counters confirms emptiness,
    /// then the queue is torn down, the slot retired and the entry
    /// tombstoned — or rolled back to FULL if a push slipped in.
    fn unpin(&self, pin: Pinned<'_>, try_reclaim: bool) {
        let e = pin.entry;
        if try_reclaim {
            let s = e.state.load(Ordering::Acquire);
            if status(s) == STATUS_FULL && pin_count(s) == 1 {
                let flow = &self.slots.slot(pin.slot_idx).item;
                if flow.pushed.load(Ordering::Acquire) == flow.popped.load(Ordering::Acquire)
                    && e.state
                        .compare_exchange(
                            s,
                            next_ver(s, STATUS_REMOVING, 0),
                            Ordering::AcqRel,
                            Ordering::Relaxed,
                        )
                        .is_ok()
                {
                    // We held the only pin and REMOVING blocks new ones,
                    // so the counters below are final: every earlier
                    // pusher's enqueue happened-before its unpin RMW,
                    // which happened-before our successful CAS.
                    if flow.pushed.load(Ordering::Acquire) == flow.popped.load(Ordering::Acquire)
                    {
                        flow.q.teardown(&self.nodes);
                        self.slots.retire(pin.slot_idx);
                        let cur = e.state.load(Ordering::Relaxed);
                        e.state.store(next_ver(cur, STATUS_TOMB, 0), Ordering::Release);
                    } else {
                        // A push landed between the first counter check
                        // and the CAS: the flow is live again.
                        let cur = e.state.load(Ordering::Relaxed);
                        e.state.store(next_ver(cur, STATUS_FULL, 0), Ordering::Release);
                    }
                    return;
                }
            }
        }
        e.state.fetch_sub(PIN_ONE, Ordering::Release);
    }

    /// Deliver a message from `peer` under `tag` (refcount move, no
    /// copy). Lock-free; wakes receivers of exactly this flow, and only
    /// when one is actually parked.
    pub fn push(&self, peer: usize, tag: u64, data: Buf) {
        let pin = self.pin(peer, tag);
        let flow = &self.slots.slot(pin.slot_idx).item;
        self.pending.fetch_add(1, Ordering::Relaxed);
        flow.q.push(&self.nodes, data);
        flow.pushed.fetch_add(1, Ordering::SeqCst);
        if flow.waiters.load(Ordering::SeqCst) > 0 {
            // Empty critical section: serializes with a parking
            // receiver's "re-check then wait" so the notify below can
            // never land in the gap (the receiver either sees the new
            // `pushed` count or is already waiting on the condvar).
            self.park_locks.fetch_add(1, Ordering::Relaxed);
            drop(flow.park.lock().unwrap());
            flow.cv.notify_all();
        }
        self.unpin(pin, false);
    }

    /// Epoch-fenced [`push`](Self::push): deliver only if `epoch` is
    /// current. A frame stamped with an older membership epoch is from
    /// a dead group generation — drop it (returns `false`, counted in
    /// [`stale_dropped`](Self::stale_dropped)) instead of letting it
    /// tag-match a collective of the re-formed group.
    pub fn push_epoch(&self, peer: usize, tag: u64, data: Buf, epoch: u64) -> bool {
        if epoch < self.epoch.load(Ordering::SeqCst) {
            self.stale_dropped.fetch_add(1, Ordering::Relaxed);
            return false;
        }
        self.push(peer, tag, data);
        true
    }

    /// Advance the membership epoch (monotonic; lower values are
    /// ignored). Subsequent [`push_epoch`](Self::push_epoch) calls with
    /// an older stamp are dropped.
    pub fn set_epoch(&self, epoch: u64) {
        self.epoch.fetch_max(epoch, Ordering::SeqCst);
    }

    /// The current membership epoch.
    pub fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::SeqCst)
    }

    /// Frames refused by epoch fencing since creation.
    pub fn stale_dropped(&self) -> u64 {
        self.stale_dropped.load(Ordering::Relaxed)
    }

    /// Dequeue one message if the flow is non-empty. The empty check is
    /// two atomic loads — a spinning receiver does not touch any cache
    /// line the pusher CASes until a message is actually present.
    fn try_take(&self, flow: &FlowSlot) -> Option<Buf> {
        if flow.pushed.load(Ordering::SeqCst) == flow.popped.load(Ordering::SeqCst) {
            return None;
        }
        let msg = flow.q.pop(&self.nodes)?;
        flow.popped.fetch_add(1, Ordering::SeqCst);
        self.pending.fetch_sub(1, Ordering::Relaxed);
        Some(msg)
    }

    /// Blocking, tag-matched receive with timeout.
    ///
    /// Perf-pass P4 (kept from the lock-based design): collective ring
    /// steps are latency-bound for small messages and a condvar
    /// sleep/wake costs ~10–20 µs per hop, so we spin briefly before
    /// parking — now on the flow's atomic counters instead of a mutex.
    pub fn pop(&self, peer: usize, tag: u64, timeout: Duration) -> Result<Buf> {
        let pin = self.pin(peer, tag);
        let res = self.pop_flow(pin.slot_idx, peer, tag, timeout);
        self.unpin(pin, true);
        res
    }

    fn pop_flow(&self, slot_idx: u32, peer: usize, tag: u64, timeout: Duration) -> Result<Buf> {
        let flow = &self.slots.slot(slot_idx).item;

        const SPIN_BUDGET: Duration = Duration::from_micros(40);
        let spin_start = Instant::now();
        loop {
            if let Some(msg) = self.try_take(flow) {
                return Ok(msg);
            }
            if self.peer_dead(peer) {
                bail!("peer {peer} lost while waiting for tag {tag} (rank failed or disconnected)");
            }
            if self.closed.load(Ordering::SeqCst) {
                bail!("mailbox closed while waiting for (peer={peer}, tag={tag})");
            }
            if spin_start.elapsed() >= SPIN_BUDGET {
                break;
            }
            std::hint::spin_loop();
        }

        let deadline = Instant::now() + timeout;
        flow.waiters.fetch_add(1, Ordering::SeqCst);
        self.park_locks.fetch_add(1, Ordering::Relaxed);
        let mut guard = flow.park.lock().unwrap();
        let res = loop {
            if let Some(msg) = self.try_take(flow) {
                break Ok(msg);
            }
            if self.peer_dead(peer) {
                break Err(anyhow!(
                    "peer {peer} lost while waiting for tag {tag} (rank failed or disconnected)"
                ));
            }
            if self.closed.load(Ordering::SeqCst) {
                break Err(anyhow!(
                    "mailbox closed while waiting for (peer={peer}, tag={tag})"
                ));
            }
            let now = Instant::now();
            if now >= deadline {
                break Err(anyhow!(
                    "recv timeout waiting for (peer={peer}, tag={tag}) — \
                     likely a collective deadlock or a dead peer"
                ));
            }
            let (g, _res) = flow.cv.wait_timeout(guard, deadline - now).unwrap();
            guard = g;
        };
        drop(guard);
        flow.waiters.fetch_sub(1, Ordering::SeqCst);
        res
    }

    /// Wake all blocked receivers with an error (mesh shutdown).
    /// Queued messages remain deliverable.
    pub fn close(&self) {
        self.closed.store(true, Ordering::SeqCst);
        self.wake_flows(None);
    }

    /// Fail exactly one peer: receivers blocked (or about to block) on
    /// any of `peer`'s flows error with `"peer N lost"`, while flows
    /// from every other peer are untouched. Messages `peer` queued
    /// before dying remain deliverable (drain-first, like [`close`]).
    ///
    /// Ranks beyond the bitmap (≥ `DEAD_WORDS * 64`) degrade to a full
    /// [`close`](Self::close) — safe, just not selective.
    pub fn close_peer(&self, peer: usize) {
        let (word, bit) = (peer / 64, peer % 64);
        if word >= DEAD_WORDS {
            self.close();
            return;
        }
        self.dead[word].fetch_or(1 << bit, Ordering::SeqCst);
        self.wake_flows(Some(peer));
    }

    /// Has [`close_peer`](Self::close_peer) been called for `peer`?
    pub fn peer_dead(&self, peer: usize) -> bool {
        let (word, bit) = (peer / 64, peer % 64);
        if word >= DEAD_WORDS {
            return self.closed.load(Ordering::SeqCst);
        }
        self.dead[word].load(Ordering::SeqCst) & (1 << bit) != 0
    }

    /// Wake parked receivers so they re-check `closed` / the dead-peer
    /// bitmap. `only_peer` filters which flows are signaled; a spurious
    /// wake of an unrelated flow would be harmless, a missed wake would
    /// not, so the peer filter is read under the pin.
    fn wake_flows(&self, only_peer: Option<usize>) {
        for shard in self.shards.iter() {
            for e in shard.entries.iter() {
                let mut s = e.state.load(Ordering::Acquire);
                loop {
                    if status(s) != STATUS_FULL {
                        break; // no live flow here, nobody can be parked
                    }
                    // Pin so the slot cannot be reclaimed mid-wake (a
                    // parked waiter holds its own pin, so any entry
                    // with waiters is FULL and stays FULL).
                    match e.state.compare_exchange_weak(
                        s,
                        s + PIN_ONE,
                        Ordering::AcqRel,
                        Ordering::Acquire,
                    ) {
                        Ok(_) => {
                            let matches = match only_peer {
                                Some(p) => e.peer.load(Ordering::Relaxed) == p as u64,
                                None => true,
                            };
                            if matches {
                                let idx = ref_idx(e.slot.load(Ordering::Acquire));
                                let flow = &self.slots.slot(idx).item;
                                self.park_locks.fetch_add(1, Ordering::Relaxed);
                                drop(flow.park.lock().unwrap());
                                flow.cv.notify_all();
                            }
                            e.state.fetch_sub(PIN_ONE, Ordering::Release);
                            break;
                        }
                        Err(cur) => s = cur,
                    }
                }
            }
        }
    }

    /// Number of queued (undelivered) messages — a relaxed atomic
    /// gauge, O(1), exact when the mailbox is quiescent.
    pub fn pending(&self) -> usize {
        self.pending.load(Ordering::Relaxed) as usize
    }

    /// Number of live (non-reclaimed) flow entries — for tests and
    /// diagnostics of drained-slot reclamation. O(table size).
    pub fn live_flows(&self) -> usize {
        self.shards
            .iter()
            .map(|sh| {
                sh.entries
                    .iter()
                    .filter(|e| status(e.state.load(Ordering::Acquire)) == STATUS_FULL)
                    .count()
            })
            .sum()
    }

    /// How many times the per-flow parking mutex was acquired —
    /// diagnostic for the lock-free fast-path guarantee (it is the only
    /// mutex in the mailbox, so a zero delta proves a code path never
    /// left the lock-free fast path).
    pub fn park_lock_count(&self) -> u64 {
        self.park_locks.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn buf(bytes: &[u8]) -> Buf {
        Buf::copy_from_slice(bytes)
    }

    #[test]
    fn push_pop_fifo_per_tag() {
        let mb = Mailbox::new();
        mb.push(0, 7, buf(&[1]));
        mb.push(0, 7, buf(&[2]));
        mb.push(0, 9, buf(&[3]));
        assert_eq!(mb.pop(0, 7, RECV_TIMEOUT).unwrap(), vec![1]);
        assert_eq!(mb.pop(0, 9, RECV_TIMEOUT).unwrap(), vec![3]);
        assert_eq!(mb.pop(0, 7, RECV_TIMEOUT).unwrap(), vec![2]);
        assert_eq!(mb.pending(), 0, "drained slots are removed");
    }

    #[test]
    fn tags_do_not_cross_match() {
        let mb = Mailbox::new();
        mb.push(1, 5, buf(&[42]));
        assert!(mb.pop(1, 6, Duration::from_millis(50)).is_err());
        assert_eq!(mb.pop(1, 5, RECV_TIMEOUT).unwrap(), vec![42]);
    }

    #[test]
    fn blocking_pop_wakes_on_push() {
        let mb = Arc::new(Mailbox::new());
        let mb2 = mb.clone();
        let h = std::thread::spawn(move || mb2.pop(3, 1, RECV_TIMEOUT).unwrap());
        std::thread::sleep(Duration::from_millis(20));
        mb.push(3, 1, buf(&[9, 9]));
        assert_eq!(h.join().unwrap(), vec![9, 9]);
    }

    #[test]
    fn timeout_is_an_error() {
        let mb = Mailbox::new();
        let err = mb.pop(0, 0, Duration::from_millis(30)).unwrap_err();
        assert!(err.to_string().contains("timeout"));
    }

    #[test]
    fn close_unblocks_receivers() {
        let mb = Arc::new(Mailbox::new());
        let mb2 = mb.clone();
        let h = std::thread::spawn(move || mb2.pop(0, 0, Duration::from_secs(30)));
        std::thread::sleep(Duration::from_millis(20));
        mb.close();
        assert!(h.join().unwrap().is_err());
    }

    #[test]
    fn close_then_pop_errors_without_waiting() {
        let mb = Mailbox::new();
        mb.close();
        let t0 = Instant::now();
        assert!(mb.pop(0, 1, Duration::from_secs(30)).is_err());
        assert!(t0.elapsed() < Duration::from_secs(1));
    }

    #[test]
    fn zero_length_messages_deliver() {
        let mb = Mailbox::new();
        mb.push(2, 4, Buf::empty());
        assert!(mb.pop(2, 4, RECV_TIMEOUT).unwrap().is_empty());
    }

    #[test]
    fn concurrent_flows_do_not_interfere() {
        let mb = Arc::new(Mailbox::new());
        std::thread::scope(|s| {
            for tag in 0..8_u64 {
                let mb = mb.clone();
                s.spawn(move || {
                    for i in 0..50_u8 {
                        mb.push(tag as usize, tag, buf(&[i]));
                    }
                });
                let mb = mb.clone();
                s.spawn(move || {
                    for i in 0..50_u8 {
                        let got = mb.pop(tag as usize, tag, RECV_TIMEOUT).unwrap();
                        assert_eq!(got, vec![i], "per-flow FIFO must hold");
                    }
                });
            }
        });
        assert_eq!(mb.pending(), 0);
    }

    #[test]
    fn fast_path_takes_no_park_lock() {
        // The ISSUE 6 acceptance assertion: push and data-ready pop
        // never touch a mutex. The parking mutex is the only mutex in
        // the mailbox, so its acquisition counter staying at zero over
        // a push/pop storm proves the fast path is lock-free.
        let mb = Mailbox::new();
        for round in 0..10 {
            for f in 0..64_u64 {
                mb.push(round, f, buf(&[round as u8]));
            }
            for f in 0..64_u64 {
                assert_eq!(mb.pop(round, f, RECV_TIMEOUT).unwrap(), vec![round as u8]);
            }
        }
        assert_eq!(
            mb.park_lock_count(),
            0,
            "push / data-ready pop must not acquire the parking mutex"
        );
    }

    #[test]
    fn drained_flows_are_reclaimed() {
        // Sequential push/pop cycles: the popper always holds the only
        // pin when the flow drains, so every flow entry is reclaimed
        // (tombstoned) and every slot recycled.
        let mb = Mailbox::new();
        for round in 0..5 {
            for f in 0..100_u64 {
                mb.push(f as usize, f, buf(&[round]));
            }
            assert_eq!(mb.pending(), 100);
            assert_eq!(mb.live_flows(), 100);
            for f in 0..100_u64 {
                assert_eq!(mb.pop(f as usize, f, RECV_TIMEOUT).unwrap(), vec![round]);
            }
            assert_eq!(mb.pending(), 0);
            assert_eq!(mb.live_flows(), 0, "drained flows must be tombstoned");
        }
    }

    #[test]
    fn close_peer_fails_only_that_peers_flows() {
        let mb = Arc::new(Mailbox::new());
        let mb2 = mb.clone();
        // A receiver parked on the doomed peer...
        let doomed = std::thread::spawn(move || mb2.pop(1, 7, Duration::from_secs(30)));
        // ...and one parked on a healthy peer.
        let mb3 = mb.clone();
        let healthy = std::thread::spawn(move || mb3.pop(2, 7, Duration::from_secs(30)));
        std::thread::sleep(Duration::from_millis(30));
        mb.close_peer(1);
        let err = doomed.join().unwrap().unwrap_err();
        assert!(
            err.to_string().contains("peer 1 lost"),
            "distinct per-peer error, got: {err}"
        );
        // The healthy flow is still live: a push delivers normally.
        mb.push(2, 7, buf(&[5]));
        assert_eq!(healthy.join().unwrap().unwrap(), vec![5]);
        assert!(mb.peer_dead(1));
        assert!(!mb.peer_dead(2));
    }

    #[test]
    fn close_peer_then_pop_errors_fast_but_drains_queued() {
        let mb = Mailbox::new();
        mb.push(3, 9, buf(&[1]));
        mb.close_peer(3);
        // Queued messages from the dead peer remain deliverable...
        assert_eq!(mb.pop(3, 9, Duration::from_secs(30)).unwrap(), vec![1]);
        // ...then the flow fails promptly instead of timing out.
        let t0 = Instant::now();
        let err = mb.pop(3, 9, Duration::from_secs(30)).unwrap_err();
        assert!(err.to_string().contains("peer 3 lost"));
        assert!(t0.elapsed() < Duration::from_secs(1));
        // Unrelated peers are unaffected.
        mb.push(0, 9, buf(&[2]));
        assert_eq!(mb.pop(0, 9, RECV_TIMEOUT).unwrap(), vec![2]);
    }

    #[test]
    fn epoch_fencing_drops_stale_pushes() {
        let mb = Mailbox::new();
        assert_eq!(mb.epoch(), 0);
        assert!(mb.push_epoch(0, 1, buf(&[1]), 0), "current epoch delivers");
        mb.set_epoch(2);
        assert_eq!(mb.epoch(), 2);
        assert!(!mb.push_epoch(0, 1, buf(&[9]), 1), "stale epoch dropped");
        assert!(!mb.push_epoch(0, 1, buf(&[9]), 0), "stale epoch dropped");
        assert!(mb.push_epoch(0, 1, buf(&[2]), 2), "new epoch delivers");
        assert_eq!(mb.stale_dropped(), 2);
        // Only the epoch-0 (pre-fence) and epoch-2 frames arrive.
        assert_eq!(mb.pop(0, 1, RECV_TIMEOUT).unwrap(), vec![1]);
        assert_eq!(mb.pop(0, 1, RECV_TIMEOUT).unwrap(), vec![2]);
        assert_eq!(mb.pending(), 0);
        // set_epoch is monotonic: lower values are ignored.
        mb.set_epoch(1);
        assert_eq!(mb.epoch(), 2);
    }

    #[test]
    fn reclaimed_entries_are_reused_not_leaked() {
        // 10k one-shot tags through one mailbox: the flow table reuses
        // tombstones and the slot arena recycles, so the live count
        // stays at zero and nothing accumulates.
        let mb = Mailbox::new();
        for tag in 0..10_000_u64 {
            mb.push(1, tag, buf(&[tag as u8]));
            assert_eq!(mb.pop(1, tag, RECV_TIMEOUT).unwrap(), vec![tag as u8]);
        }
        assert_eq!(mb.live_flows(), 0);
        assert_eq!(mb.pending(), 0);
    }
}
