//! In-process transport: one mailbox per rank, senders push directly.
//!
//! This is the "vendor library" class of path in the simulation: a
//! refcount hand-off between threads, no syscalls, no framing, no copy —
//! and since ISSUE 6 no locks either: `send` is a lock-free push into
//! the peer's slab-backed [`Mailbox`]. The intra-group collectives of
//! `NcclSim`/`CnclSim` run over this.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::bail;

use super::mailbox::{recv_timeout, Mailbox};
use super::Transport;
use crate::comm::buf::Buf;
use crate::Result;

/// Builder: create all endpoints of an in-process communicator at once.
pub struct InprocMesh;

impl InprocMesh {
    /// Returns one endpoint per rank; hand them to the worker threads.
    pub fn new(world: usize) -> Vec<InprocEndpoint> {
        let mailboxes: Vec<Arc<Mailbox>> = (0..world).map(|_| Arc::new(Mailbox::new())).collect();
        (0..world)
            .map(|rank| InprocEndpoint {
                rank,
                mailboxes: mailboxes.clone(),
                epoch: AtomicU64::new(0),
            })
            .collect()
    }
}

/// One rank's endpoint in an in-process mesh.
pub struct InprocEndpoint {
    rank: usize,
    /// All ranks' mailboxes; `send(j, ..)` pushes into `mailboxes[j]`.
    mailboxes: Vec<Arc<Mailbox>>,
    /// This endpoint's membership epoch stamp: sends carry it, and the
    /// receiving mailbox drops stamps older than its own fence.
    epoch: AtomicU64,
}

impl InprocEndpoint {
    /// Close every mailbox, waking blocked receivers (mesh shutdown).
    pub fn shutdown(&self) {
        for mb in &self.mailboxes {
            mb.close();
        }
    }
}

impl Transport for InprocEndpoint {
    fn rank(&self) -> usize {
        self.rank
    }

    fn world(&self) -> usize {
        self.mailboxes.len()
    }

    fn send(&self, peer: usize, tag: u64, data: Buf) -> Result<()> {
        if peer >= self.mailboxes.len() {
            bail!("send to rank {peer} but world is {}", self.mailboxes.len());
        }
        let stamp = self.epoch.load(Ordering::SeqCst);
        if !self.mailboxes[peer].push_epoch(self.rank, tag, data, stamp) {
            bail!(
                "send to rank {peer} dropped by epoch fence \
                 (our epoch {stamp} is stale — this rank was removed from the group)"
            );
        }
        Ok(())
    }

    fn recv(&self, peer: usize, tag: u64) -> Result<Buf> {
        if peer >= self.mailboxes.len() {
            bail!("recv from rank {peer} but world is {}", self.mailboxes.len());
        }
        self.mailboxes[self.rank].pop(peer, tag, recv_timeout())
    }

    fn kind(&self) -> &'static str {
        "inproc"
    }

    fn stale_dropped(&self) -> u64 {
        self.mailboxes[self.rank].stale_dropped()
    }

    fn fail_peer(&self, peer: usize) {
        if peer < self.mailboxes.len() {
            self.mailboxes[self.rank].close_peer(peer);
        }
    }

    fn abort(&self) {
        self.mailboxes[self.rank].close();
    }

    fn set_epoch(&self, epoch: u64) {
        self.epoch.fetch_max(epoch, Ordering::SeqCst);
        self.mailboxes[self.rank].set_epoch(epoch);
    }

    fn epoch(&self) -> u64 {
        self.mailboxes[self.rank].epoch()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn two_rank_ping_pong() {
        let mut eps = InprocMesh::new(2);
        let e1 = eps.pop().unwrap();
        let e0 = eps.pop().unwrap();
        let h = std::thread::spawn(move || {
            let msg = e1.recv(0, 1).unwrap();
            let bumped: Vec<u8> = msg.iter().map(|b| b + 1).collect();
            e1.send(0, 2, Buf::from_vec(bumped)).unwrap();
        });
        e0.send(1, 1, Buf::copy_from_slice(&[10, 20])).unwrap();
        assert_eq!(e0.recv(1, 2).unwrap(), vec![11, 21]);
        h.join().unwrap();
    }

    #[test]
    fn world_size_and_rank() {
        let eps = InprocMesh::new(4);
        for (i, e) in eps.iter().enumerate() {
            assert_eq!(e.rank(), i);
            assert_eq!(e.world(), 4);
            assert_eq!(e.kind(), "inproc");
            assert_eq!(e.inflight_high_water(), 0);
        }
    }

    #[test]
    fn out_of_range_peer_is_error() {
        let eps = InprocMesh::new(2);
        assert!(eps[0].send(5, 0, Buf::empty()).is_err());
        assert!(eps[0].recv(5, 0).is_err());
    }

    #[test]
    fn self_send_works() {
        let eps = InprocMesh::new(1);
        eps[0].send(0, 3, Buf::copy_from_slice(&[7])).unwrap();
        assert_eq!(eps[0].recv(0, 3).unwrap(), vec![7]);
    }

    #[test]
    fn send_is_zero_copy_shared_storage() {
        // Sending a slice of a frozen Buf moves a refcount, not bytes:
        // the receiver observes the exact same backing storage window.
        let eps = InprocMesh::new(2);
        let payload = Buf::copy_from_slice(&[1, 2, 3, 4, 5, 6, 7, 8]);
        eps[0].send(1, 1, payload.slice(2, 6)).unwrap();
        let got = eps[1].recv(0, 1).unwrap();
        assert_eq!(got, vec![3, 4, 5, 6]);
    }

    #[test]
    fn fail_peer_spares_other_flows() {
        let eps = InprocMesh::new(3);
        eps[1].send(0, 1, Buf::copy_from_slice(&[7])).unwrap();
        eps[0].fail_peer(2);
        // Traffic from rank 1 still flows after rank 2 is marked dead.
        assert_eq!(eps[0].recv(1, 1).unwrap(), vec![7]);
        let err = eps[0].recv(2, 1).unwrap_err();
        assert!(err.to_string().contains("peer 2 lost"), "got: {err}");
    }

    #[test]
    fn epoch_fence_drops_stale_senders() {
        let eps = InprocMesh::new(2);
        // Rank 1 is fenced out: rank 0 (and the mailboxes) move to epoch 1.
        eps[0].set_epoch(1);
        assert_eq!(eps[0].epoch(), 1);
        // A stale rank-1 send into rank 0 is refused, loudly.
        let err = eps[1].send(0, 5, Buf::copy_from_slice(&[1])).unwrap_err();
        assert!(err.to_string().contains("epoch fence"), "got: {err}");
        // Current-epoch traffic is unaffected.
        eps[1].set_epoch(1);
        eps[1].send(0, 5, Buf::copy_from_slice(&[2])).unwrap();
        assert_eq!(eps[0].recv(1, 5).unwrap(), vec![2]);
    }

    #[test]
    fn many_threads_all_to_all() {
        let eps = InprocMesh::new(4);
        std::thread::scope(|s| {
            for e in &eps {
                s.spawn(move || {
                    for p in 0..4 {
                        e.send(p, 42, Buf::copy_from_slice(&[e.rank() as u8]))
                            .unwrap();
                    }
                    for p in 0..4 {
                        assert_eq!(e.recv(p, 42).unwrap(), vec![p as u8]);
                    }
                });
            }
        });
    }
}
