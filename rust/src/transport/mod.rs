//! Byte transports beneath the collective algorithms.
//!
//! Two implementations of the same [`Transport`] trait:
//!
//! * [`inproc`] — lock-free sharded mailboxes between threads in one
//!   process (see [`mailbox`] and [`crate::comm::slab`]).
//!   Stands in for the on-device / intra-node DMA paths a vendor library
//!   (NCCL/CNCL) would use: no syscalls, no serialization — a send is a
//!   refcount move of the payload [`Buf`] into the peer's mailbox.
//! * [`tcp`] — a full mesh of real TCP sockets (loopback or cross-host).
//!   This is the Gloo-class host path: real kernel crossings, real
//!   framing, honest overhead.
//!
//! Message addressing is `(peer, tag)`: collectives use tags to keep
//! concurrent operations (and pipeline chunks) from interleaving. Each
//! endpoint owns a [`mailbox::Mailbox`] where incoming messages are
//! buffered until the matching `recv` arrives, so send never blocks on the
//! receiver being in the right state (the PyTorch/Gloo model) — except
//! under the TCP writer's bytes-in-flight soft cap, which applies
//! backpressure to a producer racing far ahead of a slow peer.

pub mod inproc;
pub mod mailbox;
pub mod tcp;

pub use inproc::{InprocEndpoint, InprocMesh};
pub use tcp::{TcpEndpoint, TcpMesh};

use crate::comm::buf::Buf;
use crate::Result;

/// Point-to-point byte transport between the ranks of one communicator.
pub trait Transport: Send + Sync {
    /// This endpoint's rank within the communicator.
    fn rank(&self) -> usize;

    /// Number of ranks in the communicator.
    fn world(&self) -> usize;

    /// Send `data` to `peer` under `tag`. Must not block on the peer
    /// being in a matching `recv` (buffered / queued sends); bounded
    /// transports may briefly block for queue backpressure.
    fn send(&self, peer: usize, tag: u64, data: Buf) -> Result<()>;

    /// [`send`](Transport::send) with a channel hint: multi-channel
    /// transports route the frame onto channel `lane % channels()`,
    /// single-channel transports ignore the hint. Ordering contract:
    /// frames sharing a (peer, full tag, lane) triple stay FIFO; frames
    /// on different lanes may reorder on the wire, which the tag-
    /// addressed mailbox absorbs. Callers that stripe MUST derive `lane`
    /// deterministically from the full frame tag (the chunk layer uses
    /// the low [`CHUNK_TAG_BITS`](crate::collectives::chunk::CHUNK_TAG_BITS)
    /// sub-tag) so both a tag's sends and its matching receives agree on
    /// which lane carries it.
    fn send_on(&self, peer: usize, tag: u64, data: Buf, lane: usize) -> Result<()> {
        let _ = lane;
        self.send(peer, tag, data)
    }

    /// Number of parallel wire channels this endpoint opens per peer
    /// (1 for transports without channel striping).
    fn channels(&self) -> usize {
        1
    }

    /// Receive the next message from `peer` under `tag` (blocking).
    fn recv(&self, peer: usize, tag: u64) -> Result<Buf>;

    /// Human-readable transport kind (for metrics/reports).
    fn kind(&self) -> &'static str;

    /// High-water mark of bytes queued-but-unwritten toward peers over
    /// this endpoint's lifetime (non-zero only on transports with writer
    /// queues, i.e. TCP).
    fn inflight_high_water(&self) -> u64 {
        0
    }

    /// Messages this endpoint's mailbox culled as epoch-stale over its
    /// lifetime (see `Mailbox::push_epoch`); 0 for transports without a
    /// staleness fence.
    fn stale_dropped(&self) -> u64 {
        0
    }

    /// Mark `peer` as failed: receives from it error promptly with a
    /// "peer N lost" message while every other peer's traffic keeps
    /// flowing. Idempotent; default is a no-op for transports without
    /// failure tracking.
    fn fail_peer(&self, _peer: usize) {}

    /// Abort every blocked and future receive on this endpoint (used by
    /// the elastic runtime to tear a group down after a rank death).
    /// Default is a no-op.
    fn abort(&self) {}

    /// Advance the membership epoch: frames stamped with an older epoch
    /// are dropped at this endpoint's mailbox instead of delivered, and
    /// outgoing frames (on framed transports) carry the new stamp.
    /// Default is a no-op for transports that do not fence.
    fn set_epoch(&self, _epoch: u64) {}

    /// Current membership epoch of this endpoint (0 if unfenced).
    fn epoch(&self) -> u64 {
        0
    }
}

/// Convert an f32 slice to little-endian bytes (one memcpy on LE targets;
/// per-element conversion on BE). Perf-pass P1: the original per-element
/// `extend_from_slice` loop cost ~1.1 ms/MiB; the memcpy is ~60 µs/MiB
/// (see EXPERIMENTS.md §Perf).
pub fn f32s_to_bytes(xs: &[f32]) -> Vec<u8> {
    let mut out = vec![0_u8; xs.len() * 4];
    fill_f32_bytes(&mut out, xs);
    out
}

/// Serialize `xs` into `dst` as little-endian wire bytes (the allocation-
/// free core of [`f32s_to_bytes`]; `dst.len()` must be `4 * xs.len()`).
pub fn fill_f32_bytes(dst: &mut [u8], xs: &[f32]) {
    assert_eq!(dst.len(), xs.len() * 4, "destination size mismatch");
    #[cfg(target_endian = "little")]
    // SAFETY: u8 has no alignment/validity requirements; the source spans
    // exactly `dst.len()` initialized bytes; on little-endian targets the
    // in-memory representation *is* the wire format.
    unsafe {
        std::ptr::copy_nonoverlapping(xs.as_ptr() as *const u8, dst.as_mut_ptr(), dst.len());
    }
    #[cfg(target_endian = "big")]
    for (i, x) in xs.iter().enumerate() {
        dst[i * 4..i * 4 + 4].copy_from_slice(&x.to_le_bytes());
    }
}

/// Convert little-endian bytes back to f32s (one memcpy on LE targets).
pub fn bytes_to_f32s(bytes: &[u8]) -> Result<Vec<f32>> {
    if bytes.len() % 4 != 0 {
        anyhow::bail!("byte length {} not a multiple of 4", bytes.len());
    }
    let mut out = vec![0.0_f32; bytes.len() / 4];
    f32s_from_bytes(&mut out, bytes)?;
    Ok(out)
}

/// Deserialize little-endian wire bytes into `dst` (the allocation-free
/// core of [`bytes_to_f32s`]).
pub fn f32s_from_bytes(dst: &mut [f32], bytes: &[u8]) -> Result<()> {
    if bytes.len() != dst.len() * 4 {
        anyhow::bail!(
            "got {} wire bytes for {} f32 elements",
            bytes.len(),
            dst.len()
        );
    }
    #[cfg(target_endian = "little")]
    // SAFETY: the destination slice owns `dst.len() * 4` bytes of properly
    // aligned f32 storage; every bit pattern is a valid f32; u8 reads have
    // no alignment requirement.
    unsafe {
        std::ptr::copy_nonoverlapping(bytes.as_ptr(), dst.as_mut_ptr() as *mut u8, bytes.len());
    }
    #[cfg(target_endian = "big")]
    for (d, c) in dst.iter_mut().zip(bytes.chunks_exact(4)) {
        *d = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_bytes_roundtrip() {
        let xs = vec![1.5_f32, -2.25, 0.0, f32::MAX, f32::MIN_POSITIVE];
        let back = bytes_to_f32s(&f32s_to_bytes(&xs)).unwrap();
        assert_eq!(xs, back);
    }

    #[test]
    fn bad_byte_len_rejected() {
        assert!(bytes_to_f32s(&[1, 2, 3]).is_err());
        let mut dst = [0.0_f32; 2];
        assert!(f32s_from_bytes(&mut dst, &[0; 4]).is_err());
    }

    #[test]
    fn in_place_fill_matches_allocating_path() {
        let xs = vec![3.25_f32, -1.0, 1e-20];
        let mut dst = vec![0_u8; 12];
        fill_f32_bytes(&mut dst, &xs);
        assert_eq!(dst, f32s_to_bytes(&xs));
        let mut back = vec![0.0_f32; 3];
        f32s_from_bytes(&mut back, &dst).unwrap();
        assert_eq!(back, xs);
    }
}
