//! Byte transports beneath the collective algorithms.
//!
//! Two implementations of the same [`Transport`] trait:
//!
//! * [`inproc`] — lock-based mailboxes between threads in one process.
//!   Stands in for the on-device / intra-node DMA paths a vendor library
//!   (NCCL/CNCL) would use: no syscalls, no serialization beyond one copy.
//! * [`tcp`] — a full mesh of real TCP sockets (loopback or cross-host).
//!   This is the Gloo-class host path: real kernel crossings, real
//!   framing, honest overhead.
//!
//! Message addressing is `(peer, tag)`: collectives use tags to keep
//! concurrent operations (and pipeline chunks) from interleaving. Each
//! endpoint owns a [`mailbox::Mailbox`] where incoming messages are
//! buffered until the matching `recv` arrives, so send never blocks on the
//! receiver being in the right state (the PyTorch/Gloo model).

pub mod inproc;
pub mod mailbox;
pub mod tcp;

pub use inproc::{InprocEndpoint, InprocMesh};
pub use tcp::{TcpEndpoint, TcpMesh};

use crate::Result;

/// Point-to-point byte transport between the ranks of one communicator.
pub trait Transport: Send + Sync {
    /// This endpoint's rank within the communicator.
    fn rank(&self) -> usize;

    /// Number of ranks in the communicator.
    fn world(&self) -> usize;

    /// Send `data` to `peer` under `tag`. Must not block on the peer
    /// (buffered / queued sends).
    fn send(&self, peer: usize, tag: u64, data: Vec<u8>) -> Result<()>;

    /// Receive the next message from `peer` under `tag` (blocking).
    fn recv(&self, peer: usize, tag: u64) -> Result<Vec<u8>>;

    /// Human-readable transport kind (for metrics/reports).
    fn kind(&self) -> &'static str;
}

/// Convert an f32 slice to little-endian bytes (one memcpy on LE targets;
/// per-element conversion on BE). Perf-pass P1: the original per-element
/// `extend_from_slice` loop cost ~1.1 ms/MiB; the memcpy is ~60 µs/MiB
/// (see EXPERIMENTS.md §Perf).
pub fn f32s_to_bytes(xs: &[f32]) -> Vec<u8> {
    let n = xs.len() * 4;
    let mut out = vec![0_u8; n];
    #[cfg(target_endian = "little")]
    // SAFETY: u8 has no alignment/validity requirements; the source spans
    // exactly `n` initialized bytes; on little-endian targets the in-memory
    // representation *is* the wire format.
    unsafe {
        std::ptr::copy_nonoverlapping(xs.as_ptr() as *const u8, out.as_mut_ptr(), n);
    }
    #[cfg(target_endian = "big")]
    for (i, x) in xs.iter().enumerate() {
        out[i * 4..i * 4 + 4].copy_from_slice(&x.to_le_bytes());
    }
    out
}

/// Convert little-endian bytes back to f32s (one memcpy on LE targets).
pub fn bytes_to_f32s(bytes: &[u8]) -> Result<Vec<f32>> {
    if bytes.len() % 4 != 0 {
        anyhow::bail!("byte length {} not a multiple of 4", bytes.len());
    }
    let n = bytes.len() / 4;
    let mut out = vec![0.0_f32; n];
    #[cfg(target_endian = "little")]
    // SAFETY: the destination Vec owns `n * 4` bytes of properly aligned
    // f32 storage; every bit pattern is a valid f32.
    unsafe {
        std::ptr::copy_nonoverlapping(bytes.as_ptr(), out.as_mut_ptr() as *mut u8, bytes.len());
    }
    #[cfg(target_endian = "big")]
    for (i, c) in bytes.chunks_exact(4).enumerate() {
        out[i] = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_bytes_roundtrip() {
        let xs = vec![1.5_f32, -2.25, 0.0, f32::MAX, f32::MIN_POSITIVE];
        let back = bytes_to_f32s(&f32s_to_bytes(&xs)).unwrap();
        assert_eq!(xs, back);
    }

    #[test]
    fn bad_byte_len_rejected() {
        assert!(bytes_to_f32s(&[1, 2, 3]).is_err());
    }
}
