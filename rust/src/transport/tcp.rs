//! TCP mesh transport: the host-staged (Gloo-class) path.
//!
//! A full mesh of real sockets. Each connection gets a writer thread
//! (drains a queue, so `send` never blocks on the peer's recv state —
//! avoiding the classic ring-collective head-of-line deadlock when both
//! peers write simultaneously) and a reader thread (demuxes frames into
//! pooled buffers in the rank's [`Mailbox`]).
//!
//! Zero-copy data plane: a queued frame is a [`Buf`] (refcount move into
//! the writer), and the reader fills a [`BufPool`] buffer per frame, so
//! steady-state traffic allocates nothing. The writer queue is *bounded*
//! in bytes: a producer racing ahead of a slow peer blocks in `send`
//! (soft cap — it still errors out after the recv timeout instead of
//! deadlocking on a dead peer), and the endpoint exposes a
//! bytes-in-flight high-water gauge for `CommStats`.
//!
//! Frame format (little-endian):
//! `[tag: u64][epoch: u64][len: u64][payload: len bytes]`
//! The sender's rank is exchanged once at connection setup. The epoch
//! stamp is the sender's membership epoch at write time; the receiving
//! mailbox drops frames stamped older than its own fence (see
//! [`Mailbox::push_epoch`]), so traffic from a dead group generation
//! can never tag-match a collective of the re-formed group.
//!
//! Failure containment (ISSUE 7): a broken link fails *only* that
//! peer's flows ([`Mailbox::close_peer`] — receivers get a distinct
//! "peer N lost" error), and the wire length field is validated against
//! `KAITIAN_MAX_FRAME_BYTES` before it reaches the buffer pool, so a
//! corrupt or hostile header is a peer failure, not a near-unbounded
//! allocation.

use std::io::{BufReader, BufWriter, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex, OnceLock};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{bail, Context};

use super::mailbox::{recv_timeout, Mailbox};
use super::Transport;
use crate::comm::buf::{Buf, BufPool};
use crate::Result;

/// Default bytes-in-flight soft cap per endpoint (all peers combined).
/// Overridable via `KAITIAN_TCP_INFLIGHT_CAP` (`0` disables the cap).
pub const DEFAULT_INFLIGHT_CAP: u64 = 64 << 20;

/// The configured soft cap (`None` = unbounded, the pre-refactor
/// behavior). A malformed `KAITIAN_TCP_INFLIGHT_CAP` falls back to the
/// default with a one-time stderr warning (never silently).
fn inflight_cap() -> Option<u64> {
    static CACHED: OnceLock<Option<u64>> = OnceLock::new();
    *CACHED.get_or_init(|| {
        match crate::util::env_or_warn("KAITIAN_TCP_INFLIGHT_CAP", DEFAULT_INFLIGHT_CAP) {
            0 => None,
            v => Some(v),
        }
    })
}

/// Largest frame payload a reader will accept. A wire length above this
/// is treated as a corrupt header / hostile peer: the link is failed
/// (per-peer, not whole-mailbox) instead of handing the attacker-chosen
/// length to the buffer pool. Overridable via `KAITIAN_MAX_FRAME_BYTES`
/// (`0` disables the check).
pub const DEFAULT_MAX_FRAME_BYTES: u64 = 256 << 20;

fn max_frame_bytes() -> Option<u64> {
    static CACHED: OnceLock<Option<u64>> = OnceLock::new();
    *CACHED.get_or_init(|| {
        match crate::util::env_or_warn("KAITIAN_MAX_FRAME_BYTES", DEFAULT_MAX_FRAME_BYTES) {
            0 => None,
            v => Some(v),
        }
    })
}

/// Bytes queued to writer threads but not yet written to a socket.
/// `add` applies the soft-cap backpressure; writers call `sub` after the
/// frame hits the wire (or `poison` when the link dies, so blocked
/// senders fail fast instead of waiting out the cap).
///
/// Lock-free on the send path (ISSUE 6): admission is a CAS on the byte
/// counter, and the mutex/condvar pair exists only for parking a sender
/// that is actually over the cap — writers signal it only when the
/// `waiters` gauge says someone is parked.
struct Inflight {
    bytes: AtomicU64,
    dead: AtomicBool,
    /// Senders currently parked (or about to park) on `cv`.
    waiters: AtomicU32,
    park: Mutex<()>,
    cv: Condvar,
    cap: Option<u64>,
    high_water: AtomicU64,
}

impl Inflight {
    fn new(cap: Option<u64>) -> Self {
        Self {
            bytes: AtomicU64::new(0),
            dead: AtomicBool::new(false),
            waiters: AtomicU32::new(0),
            park: Mutex::new(()),
            cv: Condvar::new(),
            cap,
            high_water: AtomicU64::new(0),
        }
    }

    /// Account `n` queued bytes, blocking while the cap is exceeded.
    fn add(&self, n: u64) -> Result<()> {
        if self.dead.load(Ordering::SeqCst) {
            bail!("tcp link closed (writer thread gone)");
        }
        let Some(cap) = self.cap else {
            // Uncapped: one relaxed add, no admission control.
            let now = self.bytes.fetch_add(n, Ordering::Relaxed) + n;
            self.high_water.fetch_max(now, Ordering::Relaxed);
            return Ok(());
        };
        let deadline = std::time::Instant::now() + recv_timeout();
        let mut cur = self.bytes.load(Ordering::Relaxed);
        loop {
            // Always admit at least one frame so an oversize frame can
            // never wedge the queue.
            if cur == 0 || cur + n <= cap {
                match self.bytes.compare_exchange_weak(
                    cur,
                    cur + n,
                    Ordering::AcqRel,
                    Ordering::Relaxed,
                ) {
                    Ok(_) => {
                        self.high_water.fetch_max(cur + n, Ordering::Relaxed);
                        return Ok(());
                    }
                    Err(c) => {
                        cur = c;
                        continue;
                    }
                }
            }
            if self.dead.load(Ordering::SeqCst) {
                bail!("tcp link closed with {cur} bytes in flight");
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                bail!("tcp send backpressure timeout: {cur} bytes in flight (cap {cap})");
            }
            // Over the cap: park until a writer drains bytes. This is
            // the only path that touches the mutex.
            self.waiters.fetch_add(1, Ordering::SeqCst);
            let guard = self.park.lock().unwrap();
            cur = self.bytes.load(Ordering::SeqCst);
            if cur != 0 && cur + n > cap && !self.dead.load(Ordering::SeqCst) {
                let (g, _) = self.cv.wait_timeout(guard, deadline - now).unwrap();
                drop(g);
            } else {
                drop(guard);
            }
            self.waiters.fetch_sub(1, Ordering::SeqCst);
            cur = self.bytes.load(Ordering::Relaxed);
        }
    }

    fn sub(&self, n: u64) {
        self.bytes.fetch_sub(n, Ordering::SeqCst);
        if self.waiters.load(Ordering::SeqCst) > 0 {
            // Empty critical section: orders the wake after a parking
            // sender's "re-check then wait".
            drop(self.park.lock().unwrap());
            self.cv.notify_all();
        }
    }

    fn poison(&self) {
        self.dead.store(true, Ordering::SeqCst);
        drop(self.park.lock().unwrap());
        self.cv.notify_all();
    }
}

/// Builder for a TCP mesh communicator.
pub struct TcpMesh;

impl TcpMesh {
    /// Create an all-loopback mesh for `world` ranks in one process
    /// (used by tests and the single-host launcher). Returns endpoints.
    pub fn loopback(world: usize) -> Result<Vec<TcpEndpoint>> {
        Self::loopback_with_cap(world, inflight_cap())
    }

    /// Loopback mesh with an explicit bytes-in-flight soft cap
    /// (`None` = unbounded). Tests and benches use this to exercise
    /// writer backpressure deterministically.
    pub fn loopback_with_cap(world: usize, cap: Option<u64>) -> Result<Vec<TcpEndpoint>> {
        // Bind one listener per rank on an ephemeral port.
        let listeners: Vec<TcpListener> = (0..world)
            .map(|_| TcpListener::bind("127.0.0.1:0").context("bind loopback"))
            .collect::<Result<_>>()?;
        let addrs: Vec<SocketAddr> = listeners
            .iter()
            .map(|l| l.local_addr().context("local_addr"))
            .collect::<Result<_>>()?;
        // Connect each rank in its own thread (dial higher ranks, accept
        // lower ranks) to avoid ordering deadlock.
        let handles: Vec<_> = listeners
            .into_iter()
            .enumerate()
            .map(|(rank, listener)| {
                let addrs = addrs.clone();
                std::thread::spawn(move || {
                    TcpEndpoint::connect_with_cap(rank, &addrs, listener, cap)
                })
            })
            .collect();
        let mut eps: Vec<TcpEndpoint> = Vec::with_capacity(world);
        for h in handles {
            eps.push(h.join().expect("mesh thread panicked")?);
        }
        eps.sort_by_key(|e| e.rank);
        Ok(eps)
    }
}

enum WriterMsg {
    Frame(u64, Buf),
    Shutdown,
}

struct PeerLink {
    queue: mpsc::Sender<WriterMsg>,
}

/// One rank's endpoint in a TCP mesh.
pub struct TcpEndpoint {
    rank: usize,
    world: usize,
    mailbox: Arc<Mailbox>,
    /// Writer queues per peer (`None` for self).
    links: Vec<Option<PeerLink>>,
    threads: Vec<JoinHandle<()>>,
    bytes_sent: Arc<AtomicU64>,
    inflight: Arc<Inflight>,
    /// Membership epoch stamped on outgoing frames (shared with the
    /// writer threads, read per frame at write time).
    epoch: Arc<AtomicU64>,
}

impl TcpEndpoint {
    /// Establish the full mesh for `rank` given everyone's listen address.
    /// Dials every higher rank; accepts connections from every lower rank.
    pub fn connect(rank: usize, addrs: &[SocketAddr], listener: TcpListener) -> Result<Self> {
        Self::connect_with_cap(rank, addrs, listener, inflight_cap())
    }

    /// [`TcpEndpoint::connect`] with an explicit writer-queue soft cap.
    pub fn connect_with_cap(
        rank: usize,
        addrs: &[SocketAddr],
        listener: TcpListener,
        cap: Option<u64>,
    ) -> Result<Self> {
        let world = addrs.len();
        let mailbox = Arc::new(Mailbox::new());
        let bytes_sent = Arc::new(AtomicU64::new(0));
        let inflight = Arc::new(Inflight::new(cap));
        let epoch = Arc::new(AtomicU64::new(0));
        let mut streams: Vec<Option<TcpStream>> = (0..world).map(|_| None).collect();

        // Dial higher ranks (retry briefly: the peer may not be listening
        // yet in multi-process mode).
        for peer in rank + 1..world {
            let mut attempt = 0;
            let stream = loop {
                match TcpStream::connect(addrs[peer]) {
                    Ok(s) => break s,
                    Err(e) if attempt < 50 => {
                        attempt += 1;
                        std::thread::sleep(Duration::from_millis(100));
                        let _ = e;
                    }
                    Err(e) => return Err(e).context(format!("dial rank {peer}")),
                }
            };
            stream.set_nodelay(true).ok();
            // Identify ourselves.
            let mut s = stream.try_clone()?;
            s.write_all(&(rank as u64).to_le_bytes())?;
            streams[peer] = Some(stream);
        }
        // Accept lower ranks.
        for _ in 0..rank {
            let (stream, _) = listener.accept().context("accept")?;
            stream.set_nodelay(true).ok();
            let mut id = [0_u8; 8];
            let mut r = stream.try_clone()?;
            r.read_exact(&mut id)?;
            let peer = u64::from_le_bytes(id) as usize;
            if peer >= world {
                bail!("peer announced invalid rank {peer}");
            }
            streams[peer] = Some(stream);
        }

        // Spawn reader + writer threads per link.
        let mut links: Vec<Option<PeerLink>> = Vec::with_capacity(world);
        let mut threads = Vec::new();
        for (peer, stream) in streams.into_iter().enumerate() {
            match stream {
                None => links.push(None),
                Some(stream) => {
                    let (tx, rx) = mpsc::channel::<WriterMsg>();
                    let write_half = stream.try_clone().context("clone for writer")?;
                    let sent = bytes_sent.clone();
                    let infl = inflight.clone();
                    let ep = epoch.clone();
                    threads.push(std::thread::spawn(move || {
                        writer_loop(write_half, rx, sent, infl, ep);
                    }));
                    let mb = mailbox.clone();
                    threads.push(std::thread::spawn(move || {
                        reader_loop(stream, peer, mb);
                    }));
                    links.push(Some(PeerLink { queue: tx }));
                }
            }
        }

        Ok(Self {
            rank,
            world,
            mailbox,
            links,
            threads,
            bytes_sent,
            inflight,
            epoch,
        })
    }

    /// Total payload bytes pushed to the wire by this endpoint.
    pub fn bytes_sent(&self) -> u64 {
        self.bytes_sent.load(Ordering::Relaxed)
    }

    /// Frames this endpoint's mailbox refused by epoch fencing.
    pub fn stale_dropped(&self) -> u64 {
        self.mailbox.stale_dropped()
    }
}

fn writer_loop(
    stream: TcpStream,
    rx: mpsc::Receiver<WriterMsg>,
    sent: Arc<AtomicU64>,
    inflight: Arc<Inflight>,
    epoch: Arc<AtomicU64>,
) {
    let mut w = BufWriter::new(stream);
    loop {
        // Drain the queue with `try_recv` and flush only once it runs
        // dry: a chunk burst coalesces into one (or few) syscalls, while
        // a lone frame still hits the wire immediately — the flush
        // happens right before the blocking `recv`, so latency-sensitive
        // single messages never sit in the buffer waiting for traffic.
        let msg = match rx.try_recv() {
            Ok(m) => m,
            Err(mpsc::TryRecvError::Empty) => {
                if w.flush().is_err() {
                    break;
                }
                match rx.recv() {
                    Ok(m) => m,
                    Err(_) => break,
                }
            }
            Err(mpsc::TryRecvError::Disconnected) => break,
        };
        match msg {
            WriterMsg::Frame(tag, data) => {
                let n = data.len() as u64;
                let ep = epoch.load(Ordering::SeqCst);
                let ok = w.write_all(&tag.to_le_bytes()).is_ok()
                    && w.write_all(&ep.to_le_bytes()).is_ok()
                    && w.write_all(&n.to_le_bytes()).is_ok()
                    && w.write_all(&data).is_ok();
                if !ok {
                    break;
                }
                sent.fetch_add(n, Ordering::Relaxed);
                inflight.sub(n);
            }
            WriterMsg::Shutdown => break,
        }
    }
    let _ = w.flush();
    inflight.poison();
    // Kernel-level shutdown (affects every duplicated fd of this
    // socket): the peer's reader sees EOF *promptly* and fails just
    // this link, instead of discovering the death via recv timeout.
    let _ = w.get_ref().shutdown(std::net::Shutdown::Both);
}

fn reader_loop(stream: TcpStream, peer: usize, mailbox: Arc<Mailbox>) {
    let mut r = BufReader::new(stream);
    loop {
        let mut hdr = [0_u8; 24];
        if r.read_exact(&mut hdr).is_err() {
            // Peer closed: fail *only* this peer's flows — receivers on
            // it error out with "peer N lost" while traffic from every
            // other rank keeps flowing.
            mailbox.close_peer(peer);
            return;
        }
        let tag = u64::from_le_bytes(hdr[0..8].try_into().unwrap());
        let epoch = u64::from_le_bytes(hdr[8..16].try_into().unwrap());
        let len = u64::from_le_bytes(hdr[16..24].try_into().unwrap());
        // A corrupt or hostile header must not reach the allocator: a
        // length past the cap is a peer failure, handled like a hangup.
        if let Some(cap) = max_frame_bytes() {
            if len > cap {
                eprintln!(
                    "kaitian: tcp frame from peer {peer} claims {len} bytes \
                     (cap {cap}, KAITIAN_MAX_FRAME_BYTES) — failing peer"
                );
                mailbox.close_peer(peer);
                return;
            }
        }
        // Frame lands in a pooled buffer: steady-state reads allocate
        // nothing once the pool is warm.
        let mut data = BufPool::global().take(len as usize);
        if r.read_exact(data.as_mut_slice()).is_err() {
            mailbox.close_peer(peer);
            return;
        }
        // Epoch fence: frames stamped from a dead group generation are
        // dropped here, never delivered into the re-formed group.
        mailbox.push_epoch(peer, tag, data.freeze(), epoch);
    }
}

impl Transport for TcpEndpoint {
    fn rank(&self) -> usize {
        self.rank
    }

    fn world(&self) -> usize {
        self.world
    }

    fn send(&self, peer: usize, tag: u64, data: Buf) -> Result<()> {
        if peer == self.rank {
            // Loop back locally; no socket for self.
            self.mailbox.push(peer, tag, data);
            return Ok(());
        }
        let link = self
            .links
            .get(peer)
            .and_then(|l| l.as_ref())
            .ok_or_else(|| anyhow::anyhow!("no link to rank {peer}"))?;
        self.inflight.add(data.len() as u64)?;
        link.queue
            .send(WriterMsg::Frame(tag, data))
            .map_err(|_| anyhow::anyhow!("writer thread for rank {peer} is gone"))?;
        Ok(())
    }

    fn recv(&self, peer: usize, tag: u64) -> Result<Buf> {
        self.mailbox.pop(peer, tag, recv_timeout())
    }

    fn kind(&self) -> &'static str {
        "tcp"
    }

    fn inflight_high_water(&self) -> u64 {
        self.inflight.high_water.load(Ordering::Relaxed)
    }

    fn stale_dropped(&self) -> u64 {
        self.mailbox.stale_dropped()
    }

    fn fail_peer(&self, peer: usize) {
        self.mailbox.close_peer(peer);
    }

    fn abort(&self) {
        self.mailbox.close();
    }

    fn set_epoch(&self, epoch: u64) {
        self.epoch.fetch_max(epoch, Ordering::SeqCst);
        self.mailbox.set_epoch(epoch);
    }

    fn epoch(&self) -> u64 {
        self.mailbox.epoch()
    }
}

impl Drop for TcpEndpoint {
    fn drop(&mut self) {
        for link in self.links.iter().flatten() {
            let _ = link.queue.send(WriterMsg::Shutdown);
        }
        self.mailbox.close();
        // Reader threads exit when the peer's writer closes its socket;
        // don't join (peers may drop in any order) — threads are detached
        // by dropping the handles.
        self.threads.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loopback_ping_pong() {
        let mut eps = TcpMesh::loopback(2).unwrap();
        let e1 = eps.pop().unwrap();
        let e0 = eps.pop().unwrap();
        let h = std::thread::spawn(move || {
            let msg = e1.recv(0, 1).unwrap();
            e1.send(0, 2, msg).unwrap();
        });
        e0.send(1, 1, Buf::copy_from_slice(&[1, 2, 3])).unwrap();
        assert_eq!(e0.recv(1, 2).unwrap(), vec![1, 2, 3]);
        h.join().unwrap();
    }

    #[test]
    fn four_rank_all_to_all() {
        let eps = TcpMesh::loopback(4).unwrap();
        std::thread::scope(|s| {
            for e in &eps {
                s.spawn(move || {
                    for p in 0..4 {
                        e.send(p, 9, Buf::copy_from_slice(&[e.rank() as u8; 3]))
                            .unwrap();
                    }
                    for p in 0..4 {
                        assert_eq!(e.recv(p, 9).unwrap(), vec![p as u8; 3]);
                    }
                });
            }
        });
    }

    #[test]
    fn large_message_no_deadlock() {
        // Both ranks send 4 MiB simultaneously — queued writers must
        // prevent the write-write deadlock.
        let eps = TcpMesh::loopback(2).unwrap();
        let big = Buf::from_vec(vec![0xAB_u8; 4 << 20]);
        std::thread::scope(|s| {
            for e in &eps {
                let big = big.clone();
                s.spawn(move || {
                    let peer = 1 - e.rank();
                    e.send(peer, 1, big.clone()).unwrap();
                    let got = e.recv(peer, 1).unwrap();
                    assert_eq!(got.len(), big.len());
                });
            }
        });
    }

    #[test]
    fn bytes_sent_accounting() {
        let eps = TcpMesh::loopback(2).unwrap();
        eps[0].send(1, 1, Buf::from_vec(vec![0; 1000])).unwrap();
        let _ = eps[1].recv(0, 1).unwrap();
        assert!(eps[0].bytes_sent() >= 1000);
    }

    #[test]
    fn lone_frame_flushes_promptly_and_bursts_coalesce() {
        // The writer only flushes when its queue runs dry; a single
        // queued frame must still reach the peer promptly (the flush
        // happens before the writer blocks again), and a burst must
        // arrive intact in order.
        let eps = TcpMesh::loopback(2).unwrap();
        let t0 = std::time::Instant::now();
        eps[0].send(1, 42, Buf::copy_from_slice(&[7; 64])).unwrap();
        assert_eq!(eps[1].recv(0, 42).unwrap(), vec![7_u8; 64]);
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "lone frame must not wait for more traffic"
        );
        for k in 0..200_u8 {
            eps[0]
                .send(1, 100 + k as u64, Buf::copy_from_slice(&[k; 100]))
                .unwrap();
        }
        for k in 0..200_u8 {
            assert_eq!(eps[1].recv(0, 100 + k as u64).unwrap(), vec![k; 100]);
        }
    }

    #[test]
    fn peer_disconnect_fails_only_that_peer() {
        // Rank 2 dies; the 0↔1 pair must keep exchanging traffic and
        // only receives *from rank 2* may error, with the per-peer
        // message (satellite 1: no more whole-mailbox close on one
        // peer's hangup).
        let mut eps = TcpMesh::loopback(3).unwrap();
        let e2 = eps.pop().unwrap();
        drop(e2);
        // Give the reader threads a moment to observe the hangup.
        std::thread::sleep(Duration::from_millis(100));
        let (e0, e1) = (&eps[0], &eps[1]);
        std::thread::scope(|s| {
            s.spawn(|| {
                e1.send(0, 5, Buf::copy_from_slice(&[1, 2])).unwrap();
                assert_eq!(e1.recv(0, 6).unwrap(), vec![3, 4]);
            });
            e0.send(1, 6, Buf::copy_from_slice(&[3, 4])).unwrap();
            assert_eq!(e0.recv(1, 5).unwrap(), vec![1, 2]);
        });
        let err = e0.recv(2, 99).unwrap_err();
        assert!(err.to_string().contains("peer 2 lost"), "got: {err}");
    }

    #[test]
    fn stale_epoch_frames_are_fenced() {
        let eps = TcpMesh::loopback(2).unwrap();
        // Rank 1 moves to epoch 2; rank 0 still stamps epoch 0.
        eps[1].set_epoch(2);
        eps[0].send(1, 7, Buf::copy_from_slice(&[9])).unwrap();
        // The frame arrives but is dropped at rank 1's mailbox.
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        while eps[1].stale_dropped() == 0 {
            assert!(std::time::Instant::now() < deadline, "fence never tripped");
            std::thread::sleep(Duration::from_millis(5));
        }
        // Once rank 0 joins the new epoch its frames deliver again.
        eps[0].set_epoch(2);
        eps[0].send(1, 7, Buf::copy_from_slice(&[1])).unwrap();
        assert_eq!(eps[1].recv(0, 7).unwrap(), vec![1]);
        assert_eq!(eps[1].stale_dropped(), 1);
    }

    #[test]
    fn inflight_gauge_rises_with_traffic() {
        let eps = TcpMesh::loopback(2).unwrap();
        for _ in 0..4 {
            eps[0].send(1, 7, Buf::from_vec(vec![0; 10_000])).unwrap();
        }
        for _ in 0..4 {
            let _ = eps[1].recv(0, 7).unwrap();
        }
        assert!(
            eps[0].inflight_high_water() >= 10_000,
            "at least one frame must have been observed in flight"
        );
    }
}
