//! TCP mesh transport: the host-staged (Gloo-class) path.
//!
//! A full mesh of real sockets. Each connection gets a writer thread
//! (drains a queue, so `send` never blocks on the peer's recv state —
//! avoiding the classic ring-collective head-of-line deadlock when both
//! peers write simultaneously) and a reader thread (demuxes frames into
//! pooled buffers in the rank's [`Mailbox`]).
//!
//! Zero-copy data plane: a queued frame is a [`Buf`] (refcount move into
//! the writer), and the reader fills a [`BufPool`] buffer per frame, so
//! steady-state traffic allocates nothing. The writer queue is *bounded*
//! in bytes: a producer racing ahead of a slow peer blocks in `send`
//! (soft cap — it still errors out after the recv timeout instead of
//! deadlocking on a dead peer), and the endpoint exposes a
//! bytes-in-flight high-water gauge for `CommStats`.
//!
//! Multi-channel striping (ISSUE 10): `KAITIAN_CHANNELS` / `--channels`
//! (default 1) opens N parallel connections per peer pair, each with its
//! own writer/reader thread pair and its own bytes-in-flight account, so
//! one fat link is drained by N streams instead of one. [`Transport::send_on`]
//! routes a frame onto `lane % N`; the chunk layer derives the lane from
//! the frame's low-16-bit sub-tag, so striping is deterministic and the
//! tag-addressed mailbox absorbs any cross-channel reordering. Frames
//! sharing a (peer, tag, lane) triple stay FIFO per channel.
//!
//! Frame format (little-endian):
//! `[tag: u64][epoch: u64][len: u64][payload: len bytes]`
//! Connection setup exchanges a 16-byte handshake
//! `[rank: u64][channel: u32][channel count: u32]` (it was a bare 8-byte
//! rank before channels existed): the acceptor slots the socket into its
//! per-(peer, channel) table and hard-errors on a channel-count mismatch,
//! so every rank must agree on `KAITIAN_CHANNELS`. The epoch
//! stamp is the sender's membership epoch at write time; the receiving
//! mailbox drops frames stamped older than its own fence (see
//! [`Mailbox::push_epoch`]), so traffic from a dead group generation
//! can never tag-match a collective of the re-formed group.
//!
//! Failure containment (ISSUE 7): a broken link fails *only* that
//! peer's flows ([`Mailbox::close_peer`] — receivers get a distinct
//! "peer N lost" error), and the wire length field is validated against
//! `KAITIAN_MAX_FRAME_BYTES` before it reaches the buffer pool, so a
//! corrupt or hostile header is a peer failure, not a near-unbounded
//! allocation. With channels, the first channel reader that sees
//! EOF/error fails the whole peer exactly once and shuts the sibling
//! channels' sockets, so no channel is left half-open.

use std::io::{BufReader, IoSlice, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex, OnceLock};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{bail, Context};

use super::mailbox::{recv_timeout, Mailbox};
use super::Transport;
use crate::comm::buf::{Buf, BufPool};
use crate::Result;

/// Default bytes-in-flight soft cap **per channel** (all peers on that
/// channel combined). Overridable via `KAITIAN_TCP_INFLIGHT_CAP` (`0`
/// disables the cap). With N channels the endpoint therefore admits up
/// to N x cap queued bytes — the cap bounds what each writer thread can
/// buffer, and channels are independent writers by design.
pub const DEFAULT_INFLIGHT_CAP: u64 = 64 << 20;

/// Hard ceiling on parallel connections per peer pair: past ~16 streams
/// the mesh's fd count (`world^2 * channels`) and thread count grow with
/// no bandwidth left to claim on one link.
pub const MAX_CHANNELS: usize = 16;

/// Resolved `KAITIAN_CHANNELS` (0 = not yet resolved; see [`channels`]).
static CHANNELS: AtomicUsize = AtomicUsize::new(0);

/// Parallel connections per peer pair (default 1 = the single-socket
/// wire behavior that predates channels). Resolved once from
/// `KAITIAN_CHANNELS` on first use — garbage values warn and fall back
/// to the default via [`crate::util::env::parse_or_warn`] — and clamped
/// to `1..=MAX_CHANNELS`.
pub fn channels() -> usize {
    let v = CHANNELS.load(Ordering::Relaxed);
    if v != 0 {
        return v;
    }
    let n = crate::util::env_or_warn("KAITIAN_CHANNELS", 1_usize).clamp(1, MAX_CHANNELS);
    // First resolver wins; a concurrent `set_channels` may already have
    // published a CLI override, which then takes precedence.
    let _ = CHANNELS.compare_exchange(0, n, Ordering::Relaxed, Ordering::Relaxed);
    CHANNELS.load(Ordering::Relaxed)
}

/// Install the channel count programmatically (the `--channels` CLI
/// knob). Applies to endpoints connected after the call; every rank of a
/// mesh must agree (the connection handshake hard-errors on mismatch).
pub fn set_channels(n: usize) {
    CHANNELS.store(n.clamp(1, MAX_CHANNELS), Ordering::Relaxed);
}

/// The configured soft cap (`None` = unbounded, the pre-refactor
/// behavior). A malformed `KAITIAN_TCP_INFLIGHT_CAP` falls back to the
/// default with a one-time stderr warning (never silently).
fn inflight_cap() -> Option<u64> {
    static CACHED: OnceLock<Option<u64>> = OnceLock::new();
    *CACHED.get_or_init(|| {
        match crate::util::env_or_warn("KAITIAN_TCP_INFLIGHT_CAP", DEFAULT_INFLIGHT_CAP) {
            0 => None,
            v => Some(v),
        }
    })
}

/// Largest frame payload a reader will accept. A wire length above this
/// is treated as a corrupt header / hostile peer: the link is failed
/// (per-peer, not whole-mailbox) instead of handing the attacker-chosen
/// length to the buffer pool. Overridable via `KAITIAN_MAX_FRAME_BYTES`
/// (`0` disables the check).
pub const DEFAULT_MAX_FRAME_BYTES: u64 = 256 << 20;

fn max_frame_bytes() -> Option<u64> {
    static CACHED: OnceLock<Option<u64>> = OnceLock::new();
    *CACHED.get_or_init(|| {
        match crate::util::env_or_warn("KAITIAN_MAX_FRAME_BYTES", DEFAULT_MAX_FRAME_BYTES) {
            0 => None,
            v => Some(v),
        }
    })
}

/// Bytes queued to one channel's writer threads but not yet written to a
/// socket (one account per channel — channels are independent pipes, so
/// backpressure on one never stalls another).
/// `add` applies the soft-cap backpressure; writers call `sub` after the
/// frame hits the wire (or `poison` when the link dies, so blocked
/// senders fail fast instead of waiting out the cap).
///
/// Lock-free on the send path (ISSUE 6): admission is a CAS on the byte
/// counter, and the mutex/condvar pair exists only for parking a sender
/// that is actually over the cap — writers signal it only when the
/// `waiters` gauge says someone is parked.
struct Inflight {
    bytes: AtomicU64,
    dead: AtomicBool,
    /// Senders currently parked (or about to park) on `cv`.
    waiters: AtomicU32,
    park: Mutex<()>,
    cv: Condvar,
    cap: Option<u64>,
    high_water: AtomicU64,
}

impl Inflight {
    fn new(cap: Option<u64>) -> Self {
        Self {
            bytes: AtomicU64::new(0),
            dead: AtomicBool::new(false),
            waiters: AtomicU32::new(0),
            park: Mutex::new(()),
            cv: Condvar::new(),
            cap,
            high_water: AtomicU64::new(0),
        }
    }

    /// Account `n` queued bytes, blocking while the cap is exceeded.
    fn add(&self, n: u64) -> Result<()> {
        if self.dead.load(Ordering::SeqCst) {
            bail!("tcp link closed (writer thread gone)");
        }
        let Some(cap) = self.cap else {
            // Uncapped: one relaxed add, no admission control.
            let now = self.bytes.fetch_add(n, Ordering::Relaxed) + n;
            self.high_water.fetch_max(now, Ordering::Relaxed);
            return Ok(());
        };
        let deadline = std::time::Instant::now() + recv_timeout();
        let mut cur = self.bytes.load(Ordering::Relaxed);
        loop {
            // Always admit at least one frame so an oversize frame can
            // never wedge the queue.
            if cur == 0 || cur + n <= cap {
                match self.bytes.compare_exchange_weak(
                    cur,
                    cur + n,
                    Ordering::AcqRel,
                    Ordering::Relaxed,
                ) {
                    Ok(_) => {
                        self.high_water.fetch_max(cur + n, Ordering::Relaxed);
                        return Ok(());
                    }
                    Err(c) => {
                        cur = c;
                        continue;
                    }
                }
            }
            if self.dead.load(Ordering::SeqCst) {
                bail!("tcp link closed with {cur} bytes in flight");
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                bail!("tcp send backpressure timeout: {cur} bytes in flight (cap {cap})");
            }
            // Over the cap: park until a writer drains bytes. This is
            // the only path that touches the mutex.
            self.waiters.fetch_add(1, Ordering::SeqCst);
            let guard = self.park.lock().unwrap();
            cur = self.bytes.load(Ordering::SeqCst);
            if cur != 0 && cur + n > cap && !self.dead.load(Ordering::SeqCst) {
                let (g, _) = self.cv.wait_timeout(guard, deadline - now).unwrap();
                drop(g);
            } else {
                drop(guard);
            }
            self.waiters.fetch_sub(1, Ordering::SeqCst);
            cur = self.bytes.load(Ordering::Relaxed);
        }
    }

    fn sub(&self, n: u64) {
        self.bytes.fetch_sub(n, Ordering::SeqCst);
        if self.waiters.load(Ordering::SeqCst) > 0 {
            // Empty critical section: orders the wake after a parking
            // sender's "re-check then wait".
            drop(self.park.lock().unwrap());
            self.cv.notify_all();
        }
    }

    fn poison(&self) {
        self.dead.store(true, Ordering::SeqCst);
        drop(self.park.lock().unwrap());
        self.cv.notify_all();
    }
}

/// Builder for a TCP mesh communicator.
pub struct TcpMesh;

impl TcpMesh {
    /// Create an all-loopback mesh for `world` ranks in one process
    /// (used by tests and the single-host launcher). Returns endpoints.
    pub fn loopback(world: usize) -> Result<Vec<TcpEndpoint>> {
        Self::loopback_with_cap(world, inflight_cap())
    }

    /// Loopback mesh with an explicit bytes-in-flight soft cap
    /// (`None` = unbounded). Tests and benches use this to exercise
    /// writer backpressure deterministically.
    pub fn loopback_with_cap(world: usize, cap: Option<u64>) -> Result<Vec<TcpEndpoint>> {
        Self::loopback_with(world, cap, channels())
    }

    /// Loopback mesh with an explicit soft cap (per channel) *and*
    /// channel count — the striping tests and `benches/channels.rs`
    /// compare channel counts side by side without touching the global
    /// `KAITIAN_CHANNELS` knob.
    pub fn loopback_with(
        world: usize,
        cap: Option<u64>,
        channels: usize,
    ) -> Result<Vec<TcpEndpoint>> {
        // Bind one listener per rank on an ephemeral port.
        let listeners: Vec<TcpListener> = (0..world)
            .map(|_| TcpListener::bind("127.0.0.1:0").context("bind loopback"))
            .collect::<Result<_>>()?;
        let addrs: Vec<SocketAddr> = listeners
            .iter()
            .map(|l| l.local_addr().context("local_addr"))
            .collect::<Result<_>>()?;
        // Connect each rank in its own thread (dial higher ranks, accept
        // lower ranks) to avoid ordering deadlock.
        let handles: Vec<_> = listeners
            .into_iter()
            .enumerate()
            .map(|(rank, listener)| {
                let addrs = addrs.clone();
                std::thread::spawn(move || {
                    TcpEndpoint::connect_with_opts(rank, &addrs, listener, cap, channels)
                })
            })
            .collect();
        let mut eps: Vec<TcpEndpoint> = Vec::with_capacity(world);
        for h in handles {
            eps.push(h.join().expect("mesh thread panicked")?);
        }
        eps.sort_by_key(|e| e.rank);
        Ok(eps)
    }
}

enum WriterMsg {
    Frame(u64, Buf),
    Shutdown,
}

struct PeerLink {
    queue: mpsc::Sender<WriterMsg>,
}

/// Shared death latch for all channel readers of one peer: the first
/// channel that hits EOF/error fails the peer exactly once
/// ([`Mailbox::close_peer`]) and shuts the sibling channels' sockets, so
/// a partial hangup can never leave surviving channels half-open with
/// the peer already reported lost (ISSUE 10 satellite).
struct PeerDeath {
    dead: AtomicBool,
    /// One duplicated fd per channel of this peer; `shutdown` on any
    /// clone tears down the shared socket, waking its blocked reader.
    socks: Vec<TcpStream>,
}

impl PeerDeath {
    /// First caller wins: returns `true` exactly once, after shutting
    /// every channel socket of the peer.
    fn mark(&self) -> bool {
        if self.dead.swap(true, Ordering::SeqCst) {
            return false;
        }
        for s in &self.socks {
            let _ = s.shutdown(std::net::Shutdown::Both);
        }
        true
    }
}

/// One rank's endpoint in a TCP mesh.
pub struct TcpEndpoint {
    rank: usize,
    world: usize,
    /// Parallel connections per peer pair (>= 1).
    channels: usize,
    mailbox: Arc<Mailbox>,
    /// Writer queues per peer (`None` for self), one per channel.
    links: Vec<Option<Vec<PeerLink>>>,
    threads: Vec<JoinHandle<()>>,
    /// Payload bytes written, accounted per channel (index = channel).
    bytes_sent: Vec<Arc<AtomicU64>>,
    /// Bytes-in-flight accounts, one per channel (index = channel).
    inflight: Vec<Arc<Inflight>>,
    /// Membership epoch stamped on outgoing frames (shared with the
    /// writer threads, read once per write burst).
    epoch: Arc<AtomicU64>,
}

impl TcpEndpoint {
    /// Establish the full mesh for `rank` given everyone's listen address.
    /// Dials every higher rank; accepts connections from every lower rank.
    pub fn connect(rank: usize, addrs: &[SocketAddr], listener: TcpListener) -> Result<Self> {
        Self::connect_with_cap(rank, addrs, listener, inflight_cap())
    }

    /// [`TcpEndpoint::connect`] with an explicit writer-queue soft cap
    /// (per channel); the channel count comes from the global
    /// [`channels`] knob.
    pub fn connect_with_cap(
        rank: usize,
        addrs: &[SocketAddr],
        listener: TcpListener,
        cap: Option<u64>,
    ) -> Result<Self> {
        Self::connect_with_opts(rank, addrs, listener, cap, channels())
    }

    /// [`TcpEndpoint::connect`] with explicit cap and channel count.
    ///
    /// Opens `channels` parallel connections to every higher rank and
    /// accepts `rank * channels` connections from lower ranks. Each
    /// connection starts with the 16-byte handshake
    /// `[rank: u64][channel: u32][channel count: u32]` (little-endian);
    /// the acceptor slots the socket by (rank, channel) — connections of
    /// one peer may arrive in any order — and rejects a rank out of
    /// range, a channel-count disagreement, a channel index out of
    /// range, or a duplicate (rank, channel) claim.
    pub fn connect_with_opts(
        rank: usize,
        addrs: &[SocketAddr],
        listener: TcpListener,
        cap: Option<u64>,
        channels: usize,
    ) -> Result<Self> {
        let world = addrs.len();
        let nch = channels.clamp(1, MAX_CHANNELS);
        let mailbox = Arc::new(Mailbox::new());
        let bytes_sent: Vec<Arc<AtomicU64>> =
            (0..nch).map(|_| Arc::new(AtomicU64::new(0))).collect();
        let inflight: Vec<Arc<Inflight>> =
            (0..nch).map(|_| Arc::new(Inflight::new(cap))).collect();
        let epoch = Arc::new(AtomicU64::new(0));
        let mut streams: Vec<Vec<Option<TcpStream>>> =
            (0..world).map(|_| (0..nch).map(|_| None).collect()).collect();

        // Dial higher ranks, one connection per channel (retry briefly:
        // the peer may not be listening yet in multi-process mode).
        for peer in rank + 1..world {
            for ch in 0..nch {
                let mut attempt = 0;
                let stream = loop {
                    match TcpStream::connect(addrs[peer]) {
                        Ok(s) => break s,
                        Err(e) if attempt < 50 => {
                            attempt += 1;
                            std::thread::sleep(Duration::from_millis(100));
                            let _ = e;
                        }
                        Err(e) => return Err(e).context(format!("dial rank {peer} channel {ch}")),
                    }
                };
                stream.set_nodelay(true).ok();
                // Identify ourselves: rank, channel, channel count.
                let mut hello = [0_u8; 16];
                hello[0..8].copy_from_slice(&(rank as u64).to_le_bytes());
                hello[8..12].copy_from_slice(&(ch as u32).to_le_bytes());
                hello[12..16].copy_from_slice(&(nch as u32).to_le_bytes());
                let mut s = stream.try_clone()?;
                s.write_all(&hello)?;
                streams[peer][ch] = Some(stream);
            }
        }
        // Accept lower ranks: `rank` peers x `nch` channels each, in
        // whatever order they arrive — the handshake names the slot.
        for _ in 0..rank * nch {
            let (stream, _) = listener.accept().context("accept")?;
            stream.set_nodelay(true).ok();
            let mut hello = [0_u8; 16];
            let mut r = stream.try_clone()?;
            r.read_exact(&mut hello)?;
            let peer = u64::from_le_bytes(hello[0..8].try_into().unwrap()) as usize;
            let ch = u32::from_le_bytes(hello[8..12].try_into().unwrap()) as usize;
            let peer_nch = u32::from_le_bytes(hello[12..16].try_into().unwrap()) as usize;
            if peer >= world {
                bail!("peer announced invalid rank {peer}");
            }
            if peer_nch != nch {
                bail!(
                    "peer {peer} runs {peer_nch} channels but this rank runs {nch} — \
                     KAITIAN_CHANNELS must agree on every rank"
                );
            }
            if ch >= nch {
                bail!("peer {peer} announced invalid channel {ch} (of {nch})");
            }
            if streams[peer][ch].is_some() {
                bail!("peer {peer} claimed channel {ch} twice");
            }
            streams[peer][ch] = Some(stream);
        }

        // Spawn reader + writer threads per (peer, channel) link. All of
        // one peer's readers share a PeerDeath latch so the first broken
        // channel fails the peer once and tears its siblings down.
        let mut links: Vec<Option<Vec<PeerLink>>> = Vec::with_capacity(world);
        let mut threads = Vec::new();
        for (peer, chans) in streams.into_iter().enumerate() {
            if chans.iter().all(|s| s.is_none()) {
                links.push(None); // self — loops back through the mailbox
                continue;
            }
            let socks: Vec<TcpStream> = chans
                .iter()
                .flatten()
                .map(|s| s.try_clone())
                .collect::<std::io::Result<_>>()
                .context("clone for peer shutdown")?;
            let death = Arc::new(PeerDeath {
                dead: AtomicBool::new(false),
                socks,
            });
            let mut peer_links = Vec::with_capacity(nch);
            for (ch, stream) in chans.into_iter().enumerate() {
                let stream =
                    stream.ok_or_else(|| anyhow::anyhow!("missing channel {ch} to rank {peer}"))?;
                let (tx, rx) = mpsc::channel::<WriterMsg>();
                let write_half = stream.try_clone().context("clone for writer")?;
                let sent = bytes_sent[ch].clone();
                let infl = inflight[ch].clone();
                let ep = epoch.clone();
                threads.push(std::thread::spawn(move || {
                    writer_loop(write_half, rx, sent, infl, ep);
                }));
                let mb = mailbox.clone();
                let d = death.clone();
                threads.push(std::thread::spawn(move || {
                    reader_loop(stream, peer, mb, d);
                }));
                peer_links.push(PeerLink { queue: tx });
            }
            links.push(Some(peer_links));
        }

        Ok(Self {
            rank,
            world,
            channels: nch,
            mailbox,
            links,
            threads,
            bytes_sent,
            inflight,
            epoch,
        })
    }

    /// Total payload bytes pushed to the wire by this endpoint (all
    /// channels).
    pub fn bytes_sent(&self) -> u64 {
        self.bytes_sent
            .iter()
            .map(|c| c.load(Ordering::Relaxed))
            .sum()
    }

    /// Payload bytes pushed to the wire on channel `ch` — the striping
    /// tests use this to prove eager traffic never leaves channel 0.
    pub fn bytes_sent_on(&self, ch: usize) -> u64 {
        self.bytes_sent
            .get(ch)
            .map(|c| c.load(Ordering::Relaxed))
            .unwrap_or(0)
    }

    /// Frames this endpoint's mailbox refused by epoch fencing.
    pub fn stale_dropped(&self) -> u64 {
        self.mailbox.stale_dropped()
    }
}

/// Most frames gathered into one vectored write: 2 iovecs per frame
/// (header + payload) keeps a burst well under Linux's `IOV_MAX` (1024)
/// while still amortizing the syscall over a whole chunk burst.
const MAX_BURST_FRAMES: usize = 64;

/// `write_all` over a gather list. `IoSlice::advance_slices` is too new
/// for this crate's toolchain floor, so short writes (rare on blocking
/// sockets) rebuild the slice view past the consumed prefix by hand.
fn write_all_vectored(stream: &mut TcpStream, bufs: &[IoSlice<'_>]) -> std::io::Result<()> {
    let total: usize = bufs.iter().map(|b| b.len()).sum();
    let mut done = 0_usize;
    // Cursor: first slice not fully written + byte offset into it.
    let mut idx = 0_usize;
    let mut off = 0_usize;
    while done < total {
        let wrote = if idx == 0 && off == 0 {
            stream.write_vectored(bufs)
        } else {
            let mut view: Vec<IoSlice<'_>> = Vec::with_capacity(bufs.len() - idx);
            view.push(IoSlice::new(&bufs[idx][off..]));
            for b in &bufs[idx + 1..] {
                view.push(IoSlice::new(b));
            }
            stream.write_vectored(&view)
        };
        let mut n = match wrote {
            Ok(0) => {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::WriteZero,
                    "vectored write returned 0",
                ))
            }
            Ok(n) => n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        };
        done += n;
        // Advance the cursor past `n` written bytes (zero-length payload
        // slices fall through with rem == 0).
        while n > 0 {
            let rem = bufs[idx].len() - off;
            if n >= rem {
                n -= rem;
                idx += 1;
                off = 0;
            } else {
                off += n;
                n = 0;
            }
        }
    }
    Ok(())
}

fn writer_loop(
    mut stream: TcpStream,
    rx: mpsc::Receiver<WriterMsg>,
    sent: Arc<AtomicU64>,
    inflight: Arc<Inflight>,
    epoch: Arc<AtomicU64>,
) {
    let mut shutdown = false;
    while !shutdown {
        // Block for the first frame, then drain whatever else is already
        // queued into one gather list: a chunk burst coalesces into one
        // vectored syscall, while a lone frame still hits the wire
        // immediately (flush-when-dry — nothing ever waits in a
        // userspace buffer for more traffic). A Shutdown seen mid-drain
        // still writes the frames queued before it (flush-on-shutdown).
        let first = match rx.recv() {
            Ok(WriterMsg::Frame(tag, data)) => (tag, data),
            Ok(WriterMsg::Shutdown) | Err(_) => break,
        };
        let mut frames = vec![first];
        while frames.len() < MAX_BURST_FRAMES {
            match rx.try_recv() {
                Ok(WriterMsg::Frame(tag, data)) => frames.push((tag, data)),
                Ok(WriterMsg::Shutdown) => {
                    shutdown = true;
                    break;
                }
                Err(_) => break,
            }
        }
        // One epoch stamp per burst: every frame in it was queued before
        // this load, so the stamp is at least as fresh as the per-frame
        // load it replaces.
        let ep = epoch.load(Ordering::SeqCst);
        let hdrs: Vec<[u8; 24]> = frames
            .iter()
            .map(|(tag, data)| {
                let mut h = [0_u8; 24];
                h[0..8].copy_from_slice(&tag.to_le_bytes());
                h[8..16].copy_from_slice(&ep.to_le_bytes());
                h[16..24].copy_from_slice(&(data.len() as u64).to_le_bytes());
                h
            })
            .collect();
        let mut slices: Vec<IoSlice<'_>> = Vec::with_capacity(frames.len() * 2);
        for (h, (_, data)) in hdrs.iter().zip(&frames) {
            slices.push(IoSlice::new(h));
            slices.push(IoSlice::new(data));
        }
        if write_all_vectored(&mut stream, &slices).is_err() {
            break;
        }
        for (_, data) in &frames {
            let n = data.len() as u64;
            sent.fetch_add(n, Ordering::Relaxed);
            inflight.sub(n);
        }
    }
    inflight.poison();
    // Kernel-level shutdown (affects every duplicated fd of this
    // socket): the peer's reader sees EOF *promptly* and fails just
    // this link, instead of discovering the death via recv timeout.
    let _ = stream.shutdown(std::net::Shutdown::Both);
}

/// Fail `peer` exactly once across all of its channel readers.
fn fail_link(mailbox: &Mailbox, peer: usize, death: &PeerDeath) {
    if death.mark() {
        // Fail *only* this peer's flows — receivers on it error out with
        // "peer N lost" while traffic from every other rank keeps
        // flowing; sibling channels are shut so their readers exit too.
        mailbox.close_peer(peer);
    }
}

fn reader_loop(stream: TcpStream, peer: usize, mailbox: Arc<Mailbox>, death: Arc<PeerDeath>) {
    let mut r = BufReader::new(stream);
    loop {
        let mut hdr = [0_u8; 24];
        if r.read_exact(&mut hdr).is_err() {
            fail_link(&mailbox, peer, &death);
            return;
        }
        let tag = u64::from_le_bytes(hdr[0..8].try_into().unwrap());
        let epoch = u64::from_le_bytes(hdr[8..16].try_into().unwrap());
        let len = u64::from_le_bytes(hdr[16..24].try_into().unwrap());
        // A corrupt or hostile header must not reach the allocator: a
        // length past the cap is a peer failure, handled like a hangup.
        if let Some(cap) = max_frame_bytes() {
            if len > cap {
                eprintln!(
                    "kaitian: tcp frame from peer {peer} claims {len} bytes \
                     (cap {cap}, KAITIAN_MAX_FRAME_BYTES) — failing peer"
                );
                fail_link(&mailbox, peer, &death);
                return;
            }
        }
        // Frame lands in a pooled buffer: steady-state reads allocate
        // nothing once the pool is warm.
        let mut data = BufPool::global().take(len as usize);
        if r.read_exact(data.as_mut_slice()).is_err() {
            fail_link(&mailbox, peer, &death);
            return;
        }
        // Epoch fence: frames stamped from a dead group generation are
        // dropped here, never delivered into the re-formed group.
        mailbox.push_epoch(peer, tag, data.freeze(), epoch);
    }
}

impl Transport for TcpEndpoint {
    fn rank(&self) -> usize {
        self.rank
    }

    fn world(&self) -> usize {
        self.world
    }

    fn send(&self, peer: usize, tag: u64, data: Buf) -> Result<()> {
        self.send_on(peer, tag, data, 0)
    }

    fn send_on(&self, peer: usize, tag: u64, data: Buf, lane: usize) -> Result<()> {
        if peer == self.rank {
            // Loop back locally; no socket for self.
            self.mailbox.push(peer, tag, data);
            return Ok(());
        }
        let ch = lane % self.channels;
        let link = self
            .links
            .get(peer)
            .and_then(|l| l.as_ref())
            .ok_or_else(|| anyhow::anyhow!("no link to rank {peer}"))?;
        self.inflight[ch].add(data.len() as u64)?;
        link[ch]
            .queue
            .send(WriterMsg::Frame(tag, data))
            .map_err(|_| anyhow::anyhow!("writer thread for rank {peer} channel {ch} is gone"))?;
        Ok(())
    }

    fn channels(&self) -> usize {
        self.channels
    }

    fn recv(&self, peer: usize, tag: u64) -> Result<Buf> {
        self.mailbox.pop(peer, tag, recv_timeout())
    }

    fn kind(&self) -> &'static str {
        "tcp"
    }

    fn inflight_high_water(&self) -> u64 {
        // Sum of per-channel high-water marks: exact at 1 channel; with
        // striping it upper-bounds the true combined peak, which is the
        // right direction for a backpressure gauge.
        self.inflight
            .iter()
            .map(|i| i.high_water.load(Ordering::Relaxed))
            .sum()
    }

    fn stale_dropped(&self) -> u64 {
        self.mailbox.stale_dropped()
    }

    fn fail_peer(&self, peer: usize) {
        self.mailbox.close_peer(peer);
    }

    fn abort(&self) {
        self.mailbox.close();
    }

    fn set_epoch(&self, epoch: u64) {
        self.epoch.fetch_max(epoch, Ordering::SeqCst);
        self.mailbox.set_epoch(epoch);
    }

    fn epoch(&self) -> u64 {
        self.mailbox.epoch()
    }
}

impl Drop for TcpEndpoint {
    fn drop(&mut self) {
        for link in self.links.iter().flatten().flatten() {
            let _ = link.queue.send(WriterMsg::Shutdown);
        }
        self.mailbox.close();
        // Reader threads exit when the peer's writer closes its socket;
        // don't join (peers may drop in any order) — threads are detached
        // by dropping the handles.
        self.threads.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loopback_ping_pong() {
        let mut eps = TcpMesh::loopback(2).unwrap();
        let e1 = eps.pop().unwrap();
        let e0 = eps.pop().unwrap();
        let h = std::thread::spawn(move || {
            let msg = e1.recv(0, 1).unwrap();
            e1.send(0, 2, msg).unwrap();
        });
        e0.send(1, 1, Buf::copy_from_slice(&[1, 2, 3])).unwrap();
        assert_eq!(e0.recv(1, 2).unwrap(), vec![1, 2, 3]);
        h.join().unwrap();
    }

    #[test]
    fn four_rank_all_to_all() {
        let eps = TcpMesh::loopback(4).unwrap();
        std::thread::scope(|s| {
            for e in &eps {
                s.spawn(move || {
                    for p in 0..4 {
                        e.send(p, 9, Buf::copy_from_slice(&[e.rank() as u8; 3]))
                            .unwrap();
                    }
                    for p in 0..4 {
                        assert_eq!(e.recv(p, 9).unwrap(), vec![p as u8; 3]);
                    }
                });
            }
        });
    }

    #[test]
    fn large_message_no_deadlock() {
        // Both ranks send 4 MiB simultaneously — queued writers must
        // prevent the write-write deadlock.
        let eps = TcpMesh::loopback(2).unwrap();
        let big = Buf::from_vec(vec![0xAB_u8; 4 << 20]);
        std::thread::scope(|s| {
            for e in &eps {
                let big = big.clone();
                s.spawn(move || {
                    let peer = 1 - e.rank();
                    e.send(peer, 1, big.clone()).unwrap();
                    let got = e.recv(peer, 1).unwrap();
                    assert_eq!(got.len(), big.len());
                });
            }
        });
    }

    #[test]
    fn bytes_sent_accounting() {
        let eps = TcpMesh::loopback(2).unwrap();
        eps[0].send(1, 1, Buf::from_vec(vec![0; 1000])).unwrap();
        let _ = eps[1].recv(0, 1).unwrap();
        assert!(eps[0].bytes_sent() >= 1000);
    }

    #[test]
    fn lone_frame_flushes_promptly_and_bursts_coalesce() {
        // The writer only flushes when its queue runs dry; a single
        // queued frame must still reach the peer promptly (the flush
        // happens before the writer blocks again), and a burst must
        // arrive intact in order.
        let eps = TcpMesh::loopback(2).unwrap();
        let t0 = std::time::Instant::now();
        eps[0].send(1, 42, Buf::copy_from_slice(&[7; 64])).unwrap();
        assert_eq!(eps[1].recv(0, 42).unwrap(), vec![7_u8; 64]);
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "lone frame must not wait for more traffic"
        );
        for k in 0..200_u8 {
            eps[0]
                .send(1, 100 + k as u64, Buf::copy_from_slice(&[k; 100]))
                .unwrap();
        }
        for k in 0..200_u8 {
            assert_eq!(eps[1].recv(0, 100 + k as u64).unwrap(), vec![k; 100]);
        }
    }

    #[test]
    fn peer_disconnect_fails_only_that_peer() {
        // Rank 2 dies; the 0↔1 pair must keep exchanging traffic and
        // only receives *from rank 2* may error, with the per-peer
        // message (satellite 1: no more whole-mailbox close on one
        // peer's hangup).
        let mut eps = TcpMesh::loopback(3).unwrap();
        let e2 = eps.pop().unwrap();
        drop(e2);
        // Give the reader threads a moment to observe the hangup.
        std::thread::sleep(Duration::from_millis(100));
        let (e0, e1) = (&eps[0], &eps[1]);
        std::thread::scope(|s| {
            s.spawn(|| {
                e1.send(0, 5, Buf::copy_from_slice(&[1, 2])).unwrap();
                assert_eq!(e1.recv(0, 6).unwrap(), vec![3, 4]);
            });
            e0.send(1, 6, Buf::copy_from_slice(&[3, 4])).unwrap();
            assert_eq!(e0.recv(1, 5).unwrap(), vec![1, 2]);
        });
        let err = e0.recv(2, 99).unwrap_err();
        assert!(err.to_string().contains("peer 2 lost"), "got: {err}");
    }

    #[test]
    fn stale_epoch_frames_are_fenced() {
        let eps = TcpMesh::loopback(2).unwrap();
        // Rank 1 moves to epoch 2; rank 0 still stamps epoch 0.
        eps[1].set_epoch(2);
        eps[0].send(1, 7, Buf::copy_from_slice(&[9])).unwrap();
        // The frame arrives but is dropped at rank 1's mailbox.
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        while eps[1].stale_dropped() == 0 {
            assert!(std::time::Instant::now() < deadline, "fence never tripped");
            std::thread::sleep(Duration::from_millis(5));
        }
        // Once rank 0 joins the new epoch its frames deliver again.
        eps[0].set_epoch(2);
        eps[0].send(1, 7, Buf::copy_from_slice(&[1])).unwrap();
        assert_eq!(eps[1].recv(0, 7).unwrap(), vec![1]);
        assert_eq!(eps[1].stale_dropped(), 1);
    }

    #[test]
    fn inflight_gauge_rises_with_traffic() {
        let eps = TcpMesh::loopback(2).unwrap();
        for _ in 0..4 {
            eps[0].send(1, 7, Buf::from_vec(vec![0; 10_000])).unwrap();
        }
        for _ in 0..4 {
            let _ = eps[1].recv(0, 7).unwrap();
        }
        assert!(
            eps[0].inflight_high_water() >= 10_000,
            "at least one frame must have been observed in flight"
        );
    }

    #[test]
    fn multi_channel_frames_spread_and_reassemble() {
        // 100 frames striped lane = tag over 4 channels: every channel
        // must carry traffic, and the tag-addressed mailbox must hand
        // them back in tag order regardless of wire interleaving.
        let eps = TcpMesh::loopback_with(2, None, 4).unwrap();
        assert_eq!(eps[0].channels(), 4);
        for i in 0..100_u64 {
            eps[0]
                .send_on(1, i, Buf::copy_from_slice(&[i as u8; 32]), i as usize)
                .unwrap();
        }
        for i in 0..100_u64 {
            assert_eq!(eps[1].recv(0, i).unwrap(), vec![i as u8; 32]);
        }
        for ch in 0..4 {
            assert!(
                eps[0].bytes_sent_on(ch) > 0,
                "channel {ch} carried no bytes"
            );
        }
    }

    #[test]
    fn same_tag_same_lane_stays_fifo_with_channels() {
        // FIFO contract: frames sharing (peer, tag, lane) must arrive in
        // send order even when other lanes carry unrelated traffic.
        let eps = TcpMesh::loopback_with(2, None, 4).unwrap();
        for k in 0..300_u32 {
            eps[0]
                .send_on(1, 9, Buf::copy_from_slice(&k.to_le_bytes()), 2)
                .unwrap();
            // Noise on the other lanes under different tags.
            eps[0]
                .send_on(1, 1000 + k as u64, Buf::copy_from_slice(&[0; 8]), k as usize)
                .unwrap();
        }
        for k in 0..300_u32 {
            assert_eq!(eps[1].recv(0, 9).unwrap(), k.to_le_bytes().to_vec());
        }
    }

    #[test]
    fn plain_send_stays_on_channel_zero() {
        let eps = TcpMesh::loopback_with(2, None, 4).unwrap();
        for _ in 0..8 {
            eps[0].send(1, 3, Buf::copy_from_slice(&[1; 128])).unwrap();
        }
        for _ in 0..8 {
            let _ = eps[1].recv(0, 3).unwrap();
        }
        assert!(eps[0].bytes_sent_on(0) >= 8 * 128);
        for ch in 1..4 {
            assert_eq!(
                eps[0].bytes_sent_on(ch),
                0,
                "un-laned send leaked onto channel {ch}"
            );
        }
    }

    #[test]
    fn handshake_rejects_channel_count_mismatch() {
        // Rank 0 dials with 2 channels while rank 1 expects 1: rank 1's
        // accept loop must hard-error instead of wiring a half mesh.
        let listeners: Vec<TcpListener> = (0..2)
            .map(|_| TcpListener::bind("127.0.0.1:0").unwrap())
            .collect();
        let addrs: Vec<SocketAddr> = listeners.iter().map(|l| l.local_addr().unwrap()).collect();
        let mut it = listeners.into_iter();
        let (l0, l1) = (it.next().unwrap(), it.next().unwrap());
        let a = addrs.clone();
        let h0 =
            std::thread::spawn(move || TcpEndpoint::connect_with_opts(0, &a, l0, None, 2));
        let h1 =
            std::thread::spawn(move || TcpEndpoint::connect_with_opts(1, &addrs, l1, None, 1));
        let r1 = h1.join().unwrap();
        let err = r1.err().expect("mismatched channel counts must fail");
        assert!(
            err.to_string().contains("channels"),
            "unexpected error: {err}"
        );
        // Rank 0 may or may not finish connecting before rank 1 bails;
        // either way its thread must terminate.
        let _ = h0.join().unwrap();
    }

    #[test]
    fn multi_channel_peer_death_fails_peer_once_and_fully() {
        // Drop rank 2 of a 3-rank, 4-channel mesh: all four of its
        // channels hang up, the survivors must report "peer 2 lost"
        // exactly like the single-channel path, and 0<->1 traffic must
        // keep flowing on every channel.
        let mut eps = TcpMesh::loopback_with(3, None, 4).unwrap();
        let e2 = eps.pop().unwrap();
        drop(e2);
        std::thread::sleep(Duration::from_millis(100));
        let (e0, e1) = (&eps[0], &eps[1]);
        std::thread::scope(|s| {
            s.spawn(|| {
                for lane in 0..4 {
                    e1.send_on(0, 50 + lane as u64, Buf::copy_from_slice(&[1]), lane)
                        .unwrap();
                }
                assert_eq!(e1.recv(0, 60).unwrap(), vec![9]);
            });
            e0.send(1, 60, Buf::copy_from_slice(&[9])).unwrap();
            for lane in 0..4 {
                assert_eq!(e0.recv(1, 50 + lane as u64).unwrap(), vec![1]);
            }
        });
        let err = e0.recv(2, 99).unwrap_err();
        assert!(err.to_string().contains("peer 2 lost"), "got: {err}");
    }
}
