//! TCP mesh transport: the host-staged (Gloo-class) path.
//!
//! A full mesh of real sockets. Each connection gets a writer thread
//! (drains an unbounded queue, so `send` never blocks — avoiding the
//! classic ring-collective head-of-line deadlock when both peers write
//! simultaneously) and a reader thread (demuxes frames into the rank's
//! [`Mailbox`]).
//!
//! Frame format (little-endian):
//! `[tag: u64][len: u64][payload: len bytes]`
//! The sender's rank is exchanged once at connection setup.

use std::io::{BufReader, BufWriter, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{bail, Context};

use super::mailbox::{recv_timeout, Mailbox};
use super::Transport;
use crate::Result;

/// Builder for a TCP mesh communicator.
pub struct TcpMesh;

impl TcpMesh {
    /// Create an all-loopback mesh for `world` ranks in one process
    /// (used by tests and the single-host launcher). Returns endpoints.
    pub fn loopback(world: usize) -> Result<Vec<TcpEndpoint>> {
        // Bind one listener per rank on an ephemeral port.
        let listeners: Vec<TcpListener> = (0..world)
            .map(|_| TcpListener::bind("127.0.0.1:0").context("bind loopback"))
            .collect::<Result<_>>()?;
        let addrs: Vec<SocketAddr> = listeners
            .iter()
            .map(|l| l.local_addr().context("local_addr"))
            .collect::<Result<_>>()?;
        // Connect each rank in its own thread (dial higher ranks, accept
        // lower ranks) to avoid ordering deadlock.
        let handles: Vec<_> = listeners
            .into_iter()
            .enumerate()
            .map(|(rank, listener)| {
                let addrs = addrs.clone();
                std::thread::spawn(move || TcpEndpoint::connect(rank, &addrs, listener))
            })
            .collect();
        let mut eps: Vec<TcpEndpoint> = Vec::with_capacity(world);
        for h in handles {
            eps.push(h.join().expect("mesh thread panicked")?);
        }
        eps.sort_by_key(|e| e.rank);
        Ok(eps)
    }
}

enum WriterMsg {
    Frame(u64, Vec<u8>),
    Shutdown,
}

struct PeerLink {
    queue: mpsc::Sender<WriterMsg>,
}

/// One rank's endpoint in a TCP mesh.
pub struct TcpEndpoint {
    rank: usize,
    world: usize,
    mailbox: Arc<Mailbox>,
    /// Writer queues per peer (`None` for self).
    links: Vec<Option<PeerLink>>,
    threads: Vec<JoinHandle<()>>,
    bytes_sent: Arc<AtomicU64>,
}

impl TcpEndpoint {
    /// Establish the full mesh for `rank` given everyone's listen address.
    /// Dials every higher rank; accepts connections from every lower rank.
    pub fn connect(rank: usize, addrs: &[SocketAddr], listener: TcpListener) -> Result<Self> {
        let world = addrs.len();
        let mailbox = Arc::new(Mailbox::new());
        let bytes_sent = Arc::new(AtomicU64::new(0));
        let mut streams: Vec<Option<TcpStream>> = (0..world).map(|_| None).collect();

        // Dial higher ranks (retry briefly: the peer may not be listening
        // yet in multi-process mode).
        for peer in rank + 1..world {
            let mut attempt = 0;
            let stream = loop {
                match TcpStream::connect(addrs[peer]) {
                    Ok(s) => break s,
                    Err(e) if attempt < 50 => {
                        attempt += 1;
                        std::thread::sleep(Duration::from_millis(100));
                        let _ = e;
                    }
                    Err(e) => return Err(e).context(format!("dial rank {peer}")),
                }
            };
            stream.set_nodelay(true).ok();
            // Identify ourselves.
            let mut s = stream.try_clone()?;
            s.write_all(&(rank as u64).to_le_bytes())?;
            streams[peer] = Some(stream);
        }
        // Accept lower ranks.
        for _ in 0..rank {
            let (stream, _) = listener.accept().context("accept")?;
            stream.set_nodelay(true).ok();
            let mut id = [0_u8; 8];
            let mut r = stream.try_clone()?;
            r.read_exact(&mut id)?;
            let peer = u64::from_le_bytes(id) as usize;
            if peer >= world {
                bail!("peer announced invalid rank {peer}");
            }
            streams[peer] = Some(stream);
        }

        // Spawn reader + writer threads per link.
        let mut links: Vec<Option<PeerLink>> = Vec::with_capacity(world);
        let mut threads = Vec::new();
        for (peer, stream) in streams.into_iter().enumerate() {
            match stream {
                None => links.push(None),
                Some(stream) => {
                    let (tx, rx) = mpsc::channel::<WriterMsg>();
                    let write_half = stream.try_clone().context("clone for writer")?;
                    let sent = bytes_sent.clone();
                    threads.push(std::thread::spawn(move || {
                        writer_loop(write_half, rx, sent);
                    }));
                    let mb = mailbox.clone();
                    threads.push(std::thread::spawn(move || {
                        reader_loop(stream, peer, mb);
                    }));
                    links.push(Some(PeerLink { queue: tx }));
                }
            }
        }

        Ok(Self {
            rank,
            world,
            mailbox,
            links,
            threads,
            bytes_sent,
        })
    }

    /// Total payload bytes pushed to the wire by this endpoint.
    pub fn bytes_sent(&self) -> u64 {
        self.bytes_sent.load(Ordering::Relaxed)
    }
}

fn writer_loop(stream: TcpStream, rx: mpsc::Receiver<WriterMsg>, sent: Arc<AtomicU64>) {
    let mut w = BufWriter::new(stream);
    while let Ok(msg) = rx.recv() {
        match msg {
            WriterMsg::Frame(tag, data) => {
                if w.write_all(&tag.to_le_bytes()).is_err() {
                    return;
                }
                if w.write_all(&(data.len() as u64).to_le_bytes()).is_err() {
                    return;
                }
                if w.write_all(&data).is_err() {
                    return;
                }
                // Flush eagerly: collectives are latency-sensitive and
                // message-oriented.
                if w.flush().is_err() {
                    return;
                }
                sent.fetch_add(data.len() as u64, Ordering::Relaxed);
            }
            WriterMsg::Shutdown => return,
        }
    }
}

fn reader_loop(stream: TcpStream, peer: usize, mailbox: Arc<Mailbox>) {
    let mut r = BufReader::new(stream);
    loop {
        let mut hdr = [0_u8; 16];
        if r.read_exact(&mut hdr).is_err() {
            // Peer closed: wake any blocked receivers so they error out
            // instead of hanging.
            mailbox.close();
            return;
        }
        let tag = u64::from_le_bytes(hdr[0..8].try_into().unwrap());
        let len = u64::from_le_bytes(hdr[8..16].try_into().unwrap()) as usize;
        let mut data = vec![0_u8; len];
        if r.read_exact(&mut data).is_err() {
            mailbox.close();
            return;
        }
        mailbox.push(peer, tag, data);
    }
}

impl Transport for TcpEndpoint {
    fn rank(&self) -> usize {
        self.rank
    }

    fn world(&self) -> usize {
        self.world
    }

    fn send(&self, peer: usize, tag: u64, data: Vec<u8>) -> Result<()> {
        if peer == self.rank {
            // Loop back locally; no socket for self.
            self.mailbox.push(peer, tag, data);
            return Ok(());
        }
        let link = self
            .links
            .get(peer)
            .and_then(|l| l.as_ref())
            .ok_or_else(|| anyhow::anyhow!("no link to rank {peer}"))?;
        link.queue
            .send(WriterMsg::Frame(tag, data))
            .map_err(|_| anyhow::anyhow!("writer thread for rank {peer} is gone"))?;
        Ok(())
    }

    fn recv(&self, peer: usize, tag: u64) -> Result<Vec<u8>> {
        self.mailbox.pop(peer, tag, recv_timeout())
    }

    fn kind(&self) -> &'static str {
        "tcp"
    }
}

impl Drop for TcpEndpoint {
    fn drop(&mut self) {
        for link in self.links.iter().flatten() {
            let _ = link.queue.send(WriterMsg::Shutdown);
        }
        self.mailbox.close();
        // Reader threads exit when the peer's writer closes its socket;
        // don't join (peers may drop in any order) — threads are detached
        // by dropping the handles.
        self.threads.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loopback_ping_pong() {
        let mut eps = TcpMesh::loopback(2).unwrap();
        let e1 = eps.pop().unwrap();
        let e0 = eps.pop().unwrap();
        let h = std::thread::spawn(move || {
            let msg = e1.recv(0, 1).unwrap();
            e1.send(0, 2, msg).unwrap();
        });
        e0.send(1, 1, vec![1, 2, 3]).unwrap();
        assert_eq!(e0.recv(1, 2).unwrap(), vec![1, 2, 3]);
        h.join().unwrap();
    }

    #[test]
    fn four_rank_all_to_all() {
        let eps = TcpMesh::loopback(4).unwrap();
        std::thread::scope(|s| {
            for e in &eps {
                s.spawn(move || {
                    for p in 0..4 {
                        e.send(p, 9, vec![e.rank() as u8; 3]).unwrap();
                    }
                    for p in 0..4 {
                        assert_eq!(e.recv(p, 9).unwrap(), vec![p as u8; 3]);
                    }
                });
            }
        });
    }

    #[test]
    fn large_message_no_deadlock() {
        // Both ranks send 4 MiB simultaneously — queued writers must
        // prevent the write-write deadlock.
        let eps = TcpMesh::loopback(2).unwrap();
        let big = vec![0xAB_u8; 4 << 20];
        std::thread::scope(|s| {
            for e in &eps {
                let big = big.clone();
                s.spawn(move || {
                    let peer = 1 - e.rank();
                    e.send(peer, 1, big.clone()).unwrap();
                    let got = e.recv(peer, 1).unwrap();
                    assert_eq!(got.len(), big.len());
                });
            }
        });
    }

    #[test]
    fn bytes_sent_accounting() {
        let eps = TcpMesh::loopback(2).unwrap();
        eps[0].send(1, 1, vec![0; 1000]).unwrap();
        let _ = eps[1].recv(0, 1).unwrap();
        assert!(eps[0].bytes_sent() >= 1000);
    }
}
