//! Training/communication metrics collection and report emission.

use std::collections::BTreeMap;

use crate::sched::RebalanceEvent;
use crate::util::json::Json;

/// One training step's timing breakdown for one rank.
#[derive(Debug, Clone, Default)]
pub struct StepMetrics {
    /// Samples this rank processed (its allocation).
    pub batch: usize,
    /// Bucket the batch was padded to.
    pub bucket: usize,
    /// Seconds in the local grad computation (incl. throttle).
    pub compute_s: f64,
    /// Busy seconds in gradient all-reduce (sum over buckets; pipeline
    /// stages of different buckets may run concurrently).
    pub comm_s: f64,
    /// Wall-clock comm seconds actually exposed to this step (issue →
    /// last-bucket wait of the pipelined sync).
    pub comm_exposed_s: f64,
    /// Busy comm seconds hidden by the bucket pipeline.
    pub comm_overlap_s: f64,
    /// of which: host-staging copies.
    pub stage_s: f64,
    /// Seconds in the optimizer update.
    pub update_s: f64,
    /// Bytes moved by this rank's collectives.
    pub comm_bytes: u64,
    /// Payload bytes freshly allocated by the step's collectives (pool
    /// misses; the pooled data plane drives this toward zero once warm).
    pub alloc_bytes: u64,
    /// Buffer takes served from the data-plane pool free lists.
    pub pool_hits: u64,
    /// Payload memcpy events inside the step's collectives.
    pub copies: u64,
    /// Collective stages served per algorithm label (`"ring"`,
    /// `"doubling+eager"`, …) — the size-adaptive engine's choices.
    pub algo_ops: BTreeMap<&'static str, u64>,
    /// Seconds spent blocked in the `ps_async` staleness gate waiting
    /// for a pull to be granted (the price of being *too far ahead*).
    pub ps_wait_s: f64,
    /// Seconds this step ran ahead of the slowest rank's pushed version
    /// (compute charged while `ps_lag > 0` — straggler time absorbed by
    /// the bounded-staleness window instead of a barrier).
    pub ps_ahead_s: f64,
    /// `ps_async` version lag observed at the step's pull: this worker's
    /// version minus the slowest rank's pushed version (≤ K).
    pub ps_lag: u64,
    /// Transport-level messages dropped by mailbox staleness culling
    /// (gauge: lifetime count at the last sync on this rank).
    pub stale_dropped: u64,
}

impl StepMetrics {
    /// Fold one sync's communication outcome into this step (called once
    /// per all-reduce sync, twice in sharded mode: reduce-scatter +
    /// parameter all-gather).
    pub fn absorb_sync(&mut self, sync: &crate::ddp::SyncReport) {
        self.comm_s += sync.seconds;
        self.comm_exposed_s += sync.exposed_s;
        self.comm_overlap_s += sync.overlapped_s;
        self.stage_s += sync.stage_seconds;
        self.comm_bytes += sync.bytes;
        self.alloc_bytes += sync.alloc_bytes;
        self.pool_hits += sync.pool_hits;
        self.copies += sync.copies;
        for (&label, &count) in &sync.algo_ops {
            *self.algo_ops.entry(label).or_default() += count;
        }
        self.stale_dropped = self.stale_dropped.max(sync.stale_dropped);
    }

    /// Critical-path seconds of the step. Charges the *exposed* comm time
    /// when the pipelined sync reported one (busy `comm_s` double-counts
    /// stages that ran concurrently); falls back to `comm_s` for legacy
    /// blocking flows that never set it.
    pub fn total_s(&self) -> f64 {
        let comm = if self.comm_exposed_s > 0.0 {
            self.comm_exposed_s
        } else {
            self.comm_s
        };
        self.compute_s + comm + self.update_s
    }
}

/// Aggregate over steps (per rank or cluster-wide).
#[derive(Debug, Clone, Default)]
pub struct Accumulator {
    pub steps: usize,
    pub compute_s: f64,
    pub comm_s: f64,
    pub comm_exposed_s: f64,
    pub comm_overlap_s: f64,
    pub stage_s: f64,
    pub update_s: f64,
    pub comm_bytes: u64,
    pub alloc_bytes: u64,
    pub pool_hits: u64,
    pub copies: u64,
    pub samples: usize,
    /// Collective stages served per algorithm label across all steps.
    pub algo_ops: BTreeMap<&'static str, u64>,
    /// Total seconds blocked in the `ps_async` staleness gate.
    pub ps_wait_s: f64,
    /// Total seconds run ahead of the slowest rank (`ps_async`).
    pub ps_ahead_s: f64,
    /// Max version lag observed at any pull (`ps_async`, ≤ K).
    pub ps_lag: u64,
    /// Mailbox stale-culled message count (lifetime gauge, max over
    /// steps since each step stamps the current lifetime total).
    pub stale_dropped: u64,
}

impl Accumulator {
    pub fn add(&mut self, m: &StepMetrics) {
        self.steps += 1;
        self.compute_s += m.compute_s;
        self.comm_s += m.comm_s;
        self.comm_exposed_s += m.comm_exposed_s;
        self.comm_overlap_s += m.comm_overlap_s;
        self.stage_s += m.stage_s;
        self.update_s += m.update_s;
        self.comm_bytes += m.comm_bytes;
        self.alloc_bytes += m.alloc_bytes;
        self.pool_hits += m.pool_hits;
        self.copies += m.copies;
        self.samples += m.batch;
        for (&label, &count) in &m.algo_ops {
            *self.algo_ops.entry(label).or_default() += count;
        }
        self.ps_wait_s += m.ps_wait_s;
        self.ps_ahead_s += m.ps_ahead_s;
        self.ps_lag = self.ps_lag.max(m.ps_lag);
        self.stale_dropped = self.stale_dropped.max(m.stale_dropped);
    }

    /// Critical-path seconds (see [`StepMetrics::total_s`]): exposed comm
    /// when available, busy comm otherwise.
    pub fn total_s(&self) -> f64 {
        let comm = if self.comm_exposed_s > 0.0 {
            self.comm_exposed_s
        } else {
            self.comm_s
        };
        self.compute_s + comm + self.update_s
    }

    pub fn throughput(&self) -> f64 {
        if self.total_s() > 0.0 {
            self.samples as f64 / self.total_s()
        } else {
            0.0
        }
    }

    pub fn to_json(&self) -> Json {
        let algo_ops = Json::Obj(
            self.algo_ops
                .iter()
                .map(|(&label, &count)| (label.to_string(), Json::num(count as f64)))
                .collect(),
        );
        Json::obj(vec![
            ("steps", Json::num(self.steps as f64)),
            ("compute_s", Json::num(self.compute_s)),
            ("comm_s", Json::num(self.comm_s)),
            ("comm_exposed_s", Json::num(self.comm_exposed_s)),
            ("comm_overlap_s", Json::num(self.comm_overlap_s)),
            ("stage_s", Json::num(self.stage_s)),
            ("update_s", Json::num(self.update_s)),
            ("comm_bytes", Json::num(self.comm_bytes as f64)),
            ("alloc_bytes", Json::num(self.alloc_bytes as f64)),
            ("pool_hits", Json::num(self.pool_hits as f64)),
            ("copies", Json::num(self.copies as f64)),
            ("samples", Json::num(self.samples as f64)),
            ("throughput_sps", Json::num(self.throughput())),
            ("algo_ops", algo_ops),
            ("ps_wait_s", Json::num(self.ps_wait_s)),
            ("ps_ahead_s", Json::num(self.ps_ahead_s)),
            ("ps_lag", Json::num(self.ps_lag as f64)),
            ("stale_dropped", Json::num(self.stale_dropped as f64)),
        ])
    }
}

/// End-of-run training report (returned by the trainer, consumed by
/// examples/benches/EXPERIMENTS.md).
#[derive(Debug, Clone, Default)]
pub struct TrainReport {
    pub config_name: String,
    pub cluster: String,
    pub group_mode: String,
    pub strategy: String,
    /// Gradient aggregation mode ("allreduce" or "sharded").
    pub grad_sync: String,
    pub scores: Vec<f64>,
    pub allocation: Vec<usize>,
    pub epochs: usize,
    pub steps: usize,
    /// Wall-clock seconds for the training loop.
    pub wall_s: f64,
    /// Virtual (modeled) seconds, when run under the simulator.
    pub virtual_s: Option<f64>,
    /// Mean loss per epoch (global, sample-weighted).
    pub epoch_losses: Vec<f64>,
    /// Eval accuracy per epoch (if eval ran).
    pub epoch_accuracy: Vec<f64>,
    /// Loss at every step (rank-0 view, for loss curves).
    pub step_losses: Vec<f64>,
    /// Per-rank aggregates.
    pub per_rank: Vec<Accumulator>,
    /// Rebalances the runtime controller applied (empty unless
    /// `online_adapt` was on).
    pub rebalance_events: Vec<RebalanceEvent>,
    /// Per-rank busy fraction of the straggler-bound compute window
    /// (1.0 = the straggler), approximated from aggregate compute
    /// seconds relative to the busiest rank — the same quantity
    /// `simnet::DynamicSimReport::utilization` computes per step.
    pub utilization: Vec<f64>,
}

impl TrainReport {
    pub fn final_accuracy(&self) -> Option<f64> {
        self.epoch_accuracy.last().copied()
    }

    pub fn final_loss(&self) -> Option<f64> {
        self.epoch_losses.last().copied()
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("config", Json::str(self.config_name.clone())),
            ("cluster", Json::str(self.cluster.clone())),
            ("group_mode", Json::str(self.group_mode.clone())),
            ("strategy", Json::str(self.strategy.clone())),
            ("grad_sync", Json::str(self.grad_sync.clone())),
            (
                "scores",
                Json::arr(self.scores.iter().map(|s| Json::num(*s)).collect()),
            ),
            (
                "allocation",
                Json::arr(self.allocation.iter().map(|a| Json::num(*a as f64)).collect()),
            ),
            ("epochs", Json::num(self.epochs as f64)),
            ("steps", Json::num(self.steps as f64)),
            ("wall_s", Json::num(self.wall_s)),
            (
                "virtual_s",
                self.virtual_s.map(Json::num).unwrap_or(Json::Null),
            ),
            (
                "epoch_losses",
                Json::arr(self.epoch_losses.iter().map(|l| Json::num(*l)).collect()),
            ),
            (
                "epoch_accuracy",
                Json::arr(self.epoch_accuracy.iter().map(|a| Json::num(*a)).collect()),
            ),
            (
                "per_rank",
                Json::arr(self.per_rank.iter().map(|a| a.to_json()).collect()),
            ),
            (
                "utilization",
                Json::arr(self.utilization.iter().map(|u| Json::num(*u)).collect()),
            ),
            (
                "rebalance_events",
                Json::arr(self.rebalance_events.iter().map(|e| e.to_json()).collect()),
            ),
        ])
    }

    /// Per-rank utilization from the per-rank accumulators: busy compute
    /// seconds relative to the busiest rank.
    pub fn utilization_from(per_rank: &[Accumulator]) -> Vec<f64> {
        let max = per_rank.iter().map(|a| a.compute_s).fold(0.0, f64::max);
        per_rank
            .iter()
            .map(|a| if max > 0.0 { a.compute_s / max } else { 1.0 })
            .collect()
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        format!(
            "[{}] {} mode={} strategy={} steps={} wall={} acc={} loss={}",
            self.config_name,
            self.cluster,
            self.group_mode,
            self.strategy,
            self.steps,
            crate::util::fmt_secs(self.wall_s),
            self.final_accuracy()
                .map(|a| format!("{:.1}%", a * 100.0))
                .unwrap_or_else(|| "-".into()),
            self.final_loss()
                .map(|l| format!("{l:.4}"))
                .unwrap_or_else(|| "-".into()),
        )
    }
}

/// Markdown table builder for bench harness output.
#[derive(Debug, Default)]
pub struct MarkdownTable {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl MarkdownTable {
    pub fn new(headers: &[&str]) -> Self {
        Self {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: vec![],
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        self.rows.push(cells);
        self
    }

    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("| {} |\n", self.headers.join(" | ")));
        out.push_str(&format!(
            "|{}|\n",
            self.headers.iter().map(|_| "---").collect::<Vec<_>>().join("|")
        ));
        for r in &self.rows {
            out.push_str(&format!("| {} |\n", r.join(" | ")));
        }
        out
    }
}

/// Write a JSON report under `results/` (creating the dir).
pub fn write_report(dir: &str, name: &str, entries: BTreeMap<String, Json>) -> crate::Result<String> {
    std::fs::create_dir_all(dir)?;
    let path = format!("{dir}/{name}.json");
    let json = Json::Obj(entries);
    std::fs::write(&path, json.to_string_pretty())?;
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulator_adds() {
        let mut acc = Accumulator::default();
        acc.add(&StepMetrics {
            batch: 64,
            bucket: 64,
            compute_s: 0.1,
            comm_s: 0.02,
            comm_exposed_s: 0.015,
            comm_overlap_s: 0.005,
            stage_s: 0.001,
            update_s: 0.01,
            comm_bytes: 1000,
            alloc_bytes: 4096,
            pool_hits: 2,
            copies: 6,
            algo_ops: BTreeMap::from([("ring", 3_u64), ("doubling+eager", 1)]),
            ps_wait_s: 0.002,
            ps_ahead_s: 0.1,
            ps_lag: 2,
            stale_dropped: 3,
        });
        acc.add(&StepMetrics {
            batch: 64,
            bucket: 64,
            compute_s: 0.1,
            comm_s: 0.02,
            comm_exposed_s: 0.02,
            comm_overlap_s: 0.0,
            stage_s: 0.0,
            update_s: 0.01,
            comm_bytes: 1000,
            alloc_bytes: 0,
            pool_hits: 8,
            copies: 6,
            algo_ops: BTreeMap::from([("ring", 2_u64)]),
            ps_wait_s: 0.003,
            ps_ahead_s: 0.1,
            ps_lag: 1,
            stale_dropped: 5,
        });
        assert_eq!(acc.steps, 2);
        assert_eq!(acc.samples, 128);
        assert_eq!(acc.alloc_bytes, 4096);
        assert_eq!(acc.pool_hits, 10);
        assert_eq!(acc.copies, 12);
        assert_eq!(acc.algo_ops.get("ring"), Some(&5));
        assert_eq!(acc.algo_ops.get("doubling+eager"), Some(&1));
        // ps_* seconds sum; lag and the stale-drop gauge merge by max.
        assert!((acc.ps_wait_s - 0.005).abs() < 1e-12);
        assert!((acc.ps_ahead_s - 0.2).abs() < 1e-12);
        assert_eq!(acc.ps_lag, 2);
        assert_eq!(acc.stale_dropped, 5);
        let json = Json::parse(&acc.to_json().to_string()).unwrap();
        let algo_ops = json.get("algo_ops").expect("algo_ops in report JSON");
        assert_eq!(
            algo_ops.get("ring").and_then(Json::as_f64),
            Some(5.0),
            "per-algorithm op counts must survive the JSON round trip"
        );
        // total_s charges the exposed comm (0.035), not the busy sum (0.04).
        assert!((acc.total_s() - 0.255).abs() < 1e-12);
        assert!((acc.comm_exposed_s - 0.035).abs() < 1e-12);
        assert!((acc.comm_overlap_s - 0.005).abs() < 1e-12);
        assert!(acc.throughput() > 0.0);
    }

    #[test]
    fn report_json_roundtrips() {
        let mut r = TrainReport {
            config_name: "t".into(),
            cluster: "2G+2M".into(),
            ..Default::default()
        };
        r.epoch_losses.push(1.5);
        r.utilization = vec![1.0, 0.8];
        r.rebalance_events.push(RebalanceEvent {
            step: 40,
            old_scores: vec![1.0, 1.0],
            new_scores: vec![0.5, 1.0],
            old_allocation: vec![128, 128],
            new_allocation: vec![96, 160],
            reason: "score-drift".into(),
        });
        let parsed = Json::parse(&r.to_json().to_string()).unwrap();
        assert_eq!(parsed.str_req("cluster").unwrap(), "2G+2M");
        let events = parsed.get("rebalance_events").unwrap();
        let Json::Arr(events) = events else {
            panic!("rebalance_events must be an array")
        };
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].get("reason").and_then(Json::as_str), Some("score-drift"));
    }

    #[test]
    fn utilization_relative_to_straggler() {
        let mk = |compute_s| Accumulator {
            compute_s,
            ..Default::default()
        };
        let u = TrainReport::utilization_from(&[mk(2.0), mk(1.0), mk(0.5)]);
        assert_eq!(u, vec![1.0, 0.5, 0.25]);
        assert_eq!(TrainReport::utilization_from(&[]), Vec::<f64>::new());
    }

    #[test]
    fn markdown_table_renders() {
        let mut t = MarkdownTable::new(&["config", "time"]);
        t.row(vec!["2G".into(), "236.4".into()]);
        let md = t.render();
        assert!(md.contains("| config | time |"));
        assert!(md.contains("| 2G | 236.4 |"));
    }

    #[test]
    #[should_panic(expected = "column count")]
    fn wrong_arity_row_panics() {
        MarkdownTable::new(&["a"]).row(vec!["1".into(), "2".into()]);
    }
}
