//! Configuration: JSON config files + a from-scratch CLI argument parser
//! (the vendored crate set has no `clap`).

pub mod cli;

pub use cli::Args;

use crate::group::{GroupMode, RelayKind};
use crate::sched::Strategy;
use crate::train::TrainOptions;
use crate::util::json::Json;
use crate::Result;

/// Parse a training config from JSON text (all fields optional; defaults
/// are the paper's setup — see [`TrainOptions::default`]).
pub fn train_options_from_json(text: &str) -> Result<TrainOptions> {
    let v = Json::parse(text)?;
    let mut o = TrainOptions::default();
    apply_json(&mut o, &v)?;
    Ok(o)
}

fn apply_json(o: &mut TrainOptions, v: &Json) -> Result<()> {
    if let Some(x) = v.get("preset").and_then(Json::as_str) {
        o.preset = x.to_string();
    }
    if let Some(x) = v.get("cluster").and_then(Json::as_str) {
        o.cluster = x.to_string();
    }
    if let Some(x) = v.get("group_mode").and_then(Json::as_str) {
        o.group_mode = GroupMode::parse(x)?;
    }
    if let Some(x) = v.get("relay").and_then(Json::as_str) {
        o.relay = RelayKind::parse(x)?;
    }
    if let Some(x) = v.get("strategy").and_then(Json::as_str) {
        o.strategy = Strategy::parse(x)?;
    }
    if let Some(x) = v.get("global_batch").and_then(Json::as_usize) {
        o.global_batch = x;
    }
    if let Some(x) = v.get("epochs").and_then(Json::as_usize) {
        o.epochs = x;
    }
    if let Some(x) = v.get("steps_per_epoch").and_then(Json::as_usize) {
        o.steps_per_epoch = Some(x);
    }
    if let Some(x) = v.get("dataset_len").and_then(Json::as_usize) {
        o.dataset_len = x;
    }
    if let Some(x) = v.get("eval_batches").and_then(Json::as_usize) {
        o.eval_batches = x;
    }
    if let Some(x) = v.get("lr").and_then(Json::as_f64) {
        o.lr = x as f32;
    }
    if let Some(x) = v.get("momentum").and_then(Json::as_f64) {
        o.momentum = x as f32;
    }
    if let Some(x) = v.get("weight_decay").and_then(Json::as_f64) {
        o.weight_decay = x as f32;
    }
    if let Some(x) = v.get("lr_decay").and_then(Json::as_f64) {
        o.lr_decay = x as f32;
    }
    if let Some(x) = v.get("lr_decay_epochs").and_then(Json::as_usize) {
        o.lr_decay_epochs = x;
    }
    if let Some(x) = v.get("seed").and_then(Json::as_f64) {
        o.seed = x as u64;
    }
    if let Some(x) = v.get("throttle").and_then(Json::as_bool) {
        o.throttle = x;
    }
    if let Some(x) = v.get("profile").and_then(Json::as_bool) {
        o.profile = x;
    }
    if let Some(x) = v.get("bucket_bytes").and_then(Json::as_usize) {
        o.bucket_bytes = x;
    }
    if let Some(x) = v.get("grad_sync").and_then(Json::as_str) {
        o.grad_sync = crate::ddp::GradSyncMode::parse(x)?;
    }
    if let Some(x) = v.get("staleness").and_then(Json::as_usize) {
        o.staleness = x;
    }
    if let Some(x) = v.get("ps_shards").and_then(Json::as_usize) {
        o.ps_shards = x;
    }
    if let Some(x) = v.get("algo").and_then(Json::as_str) {
        // Validate eagerly (same policy parser the runtime uses) so a
        // typo'd algorithm name fails at config load, not mid-run.
        x.parse::<crate::collectives::AlgoPolicy>()?;
        o.algo = x.to_string();
    }
    if let Some(x) = v.get("channels").and_then(Json::as_usize) {
        o.channels = x;
    }
    if let Some(x) = v.get("log_every").and_then(Json::as_usize) {
        o.log_every = x;
    }
    if let Some(x) = v.get("online_adapt").and_then(Json::as_bool) {
        o.online_adapt = x;
    }
    if let Some(x) = v.get("adapt_every").and_then(Json::as_usize) {
        o.adapt_every = x;
    }
    if let Some(x) = v.get("adapt_ema_alpha").and_then(Json::as_f64) {
        o.adapt_ema_alpha = x;
    }
    if let Some(x) = v.get("adapt_min_rel_delta").and_then(Json::as_f64) {
        o.adapt_min_rel_delta = x;
    }
    if let Some(x) = v.get("adapt_cooldown").and_then(Json::as_usize) {
        o.adapt_cooldown = x;
    }
    if let Some(x) = v.get("adapt_shift_cap").and_then(Json::as_usize) {
        o.adapt_shift_cap = x;
    }
    if let Some(x) = v.get("adapt_freshness").and_then(Json::as_usize) {
        o.adapt_freshness = x;
    }
    if let Some(x) = v.get("scenario").and_then(Json::as_str) {
        // Validate eagerly so a typo fails at config load, not mid-run.
        crate::device::Scenario::parse(x)?;
        o.scenario = x.to_string();
    }
    Ok(())
}

/// Apply CLI flag overrides (same keys as the JSON config) on top.
pub fn apply_cli_overrides(o: &mut TrainOptions, args: &Args) -> Result<()> {
    let mut pairs = Vec::new();
    for key in [
        "preset",
        "cluster",
        "group_mode",
        "relay",
        "strategy",
        "global_batch",
        "epochs",
        "steps_per_epoch",
        "dataset_len",
        "eval_batches",
        "lr",
        "momentum",
        "weight_decay",
        "lr_decay",
        "lr_decay_epochs",
        "seed",
        "bucket_bytes",
        "grad_sync",
        "staleness",
        "ps_shards",
        "algo",
        "channels",
        "log_every",
        "adapt_every",
        "adapt_ema_alpha",
        "adapt_min_rel_delta",
        "adapt_cooldown",
        "adapt_shift_cap",
        "adapt_freshness",
    ] {
        if let Some(v) = args.flag(key) {
            // Numbers stay bare; strings get quoted.
            let quoted = if v.parse::<f64>().is_ok() {
                v.to_string()
            } else {
                format!("\"{v}\"")
            };
            pairs.push(format!("\"{key}\": {quoted}"));
        }
    }
    for key in ["throttle", "profile", "online_adapt"] {
        if let Some(v) = args.flag(key) {
            pairs.push(format!("\"{key}\": {v}"));
        }
    }
    // Scenario specs are always strings — never leave a numeric-looking
    // value bare, or it would skip (and silently bypass) validation.
    if let Some(v) = args.flag("scenario") {
        pairs.push(format!("\"scenario\": \"{v}\""));
    }
    let json = format!("{{{}}}", pairs.join(","));
    apply_json(o, &Json::parse(&json)?)
}

/// Load options: optional `--config file.json`, then CLI overrides.
pub fn load_train_options(args: &Args) -> Result<TrainOptions> {
    let mut o = if let Some(path) = args.flag("config") {
        train_options_from_json(&std::fs::read_to_string(path)?)?
    } else {
        TrainOptions::default()
    };
    apply_cli_overrides(&mut o, args)?;
    Ok(o)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn algo_knob_parses_and_rejects_garbage() {
        let o = train_options_from_json(r#"{"algo": "doubling"}"#).unwrap();
        assert_eq!(o.algo, "doubling");
        assert_eq!(TrainOptions::default().algo, "adaptive");
        assert!(train_options_from_json(r#"{"algo": "bogus"}"#).is_err());
        let mut o = TrainOptions::default();
        let args = Args::parse_from(vec!["train".into(), "--algo".into(), "ring".into()]);
        apply_cli_overrides(&mut o, &args).unwrap();
        assert_eq!(o.algo, "ring");
    }

    #[test]
    fn json_config_overrides_defaults() {
        let o = train_options_from_json(
            r#"{"preset": "tinygpt", "cluster": "1G+2M", "epochs": 3,
                "strategy": "fixed:0.5,0.25,0.25", "lr": 0.02,
                "group_mode": "flat-gloo", "throttle": false}"#,
        )
        .unwrap();
        assert_eq!(o.preset, "tinygpt");
        assert_eq!(o.cluster, "1G+2M");
        assert_eq!(o.epochs, 3);
        assert!((o.lr - 0.02).abs() < 1e-9);
        assert_eq!(o.group_mode, GroupMode::FlatGloo);
        assert!(!o.throttle);
        assert_eq!(o.strategy.name(), "fixed");
    }

    #[test]
    fn empty_json_keeps_defaults() {
        let o = train_options_from_json("{}").unwrap();
        assert_eq!(o.global_batch, 256);
        assert_eq!(o.cluster, "2G+2M");
    }

    #[test]
    fn cli_overrides_apply() {
        let args = Args::parse_from(vec![
            "train".into(),
            "--cluster".into(),
            "2M".into(),
            "--epochs".into(),
            "7".into(),
            "--profile".into(),
            "false".into(),
        ]);
        let mut o = TrainOptions::default();
        apply_cli_overrides(&mut o, &args).unwrap();
        assert_eq!(o.cluster, "2M");
        assert_eq!(o.epochs, 7);
        assert!(!o.profile);
    }

    #[test]
    fn bad_strategy_in_json_is_error() {
        assert!(train_options_from_json(r#"{"strategy": "bogus"}"#).is_err());
    }

    #[test]
    fn grad_sync_mode_parses() {
        use crate::ddp::GradSyncMode;
        let o = train_options_from_json(r#"{"grad_sync": "sharded"}"#).unwrap();
        assert_eq!(o.grad_sync, GradSyncMode::Sharded);
        assert!(train_options_from_json(r#"{"grad_sync": "bogus"}"#).is_err());

        let args = Args::parse_from(vec![
            "train".into(),
            "--grad_sync".into(),
            "sharded".into(),
        ]);
        let mut o = TrainOptions::default();
        assert_eq!(o.grad_sync, GradSyncMode::AllReduce, "default is all-reduce");
        apply_cli_overrides(&mut o, &args).unwrap();
        assert_eq!(o.grad_sync, GradSyncMode::Sharded);
    }

    #[test]
    fn ps_async_knobs_parse() {
        use crate::ddp::GradSyncMode;
        let o = train_options_from_json(
            r#"{"grad_sync": "ps_async", "staleness": 4, "ps_shards": 2}"#,
        )
        .unwrap();
        assert_eq!(o.grad_sync, GradSyncMode::PsAsync);
        assert_eq!(o.staleness, 4);
        assert_eq!(o.ps_shards, 2);

        // The CLI routes the same knobs (numeric values stay bare).
        let args = Args::parse_from(vec![
            "train".into(),
            "--grad_sync".into(),
            "ps_async".into(),
            "--staleness".into(),
            "0".into(),
            "--ps_shards".into(),
            "3".into(),
        ]);
        let mut o = TrainOptions::default();
        apply_cli_overrides(&mut o, &args).unwrap();
        assert_eq!(o.grad_sync, GradSyncMode::PsAsync);
        assert_eq!(o.staleness, 0);
        assert_eq!(o.ps_shards, 3);
    }

    #[test]
    fn channels_knob_parses() {
        let o = train_options_from_json(r#"{"channels": 4}"#).unwrap();
        assert_eq!(o.channels, 4);
        assert_eq!(
            TrainOptions::default().channels,
            0,
            "default defers to KAITIAN_CHANNELS"
        );
        let args =
            Args::parse_from(vec!["train".into(), "--channels".into(), "2".into()]);
        let mut o = TrainOptions::default();
        apply_cli_overrides(&mut o, &args).unwrap();
        assert_eq!(o.channels, 2);
    }

    #[test]
    fn controller_and_scenario_knobs_parse() {
        let o = train_options_from_json(
            r#"{"online_adapt": true, "adapt_every": 5,
                "adapt_min_rel_delta": 0.1, "adapt_cooldown": 15,
                "adapt_shift_cap": 16, "adapt_freshness": 20,
                "adapt_ema_alpha": 0.3,
                "scenario": "step-change"}"#,
        )
        .unwrap();
        assert!(o.online_adapt);
        assert_eq!(o.adapt_every, 5);
        assert!((o.adapt_min_rel_delta - 0.1).abs() < 1e-12);
        assert_eq!(o.adapt_cooldown, 15);
        assert_eq!(o.adapt_shift_cap, 16);
        assert_eq!(o.adapt_freshness, 20);
        assert!((o.adapt_ema_alpha - 0.3).abs() < 1e-12);
        assert_eq!(o.scenario, "step-change");

        // Scenario typos fail at load time.
        assert!(train_options_from_json(r#"{"scenario": "bogus"}"#).is_err());

        // CLI overrides reach the same knobs, incl. per-rank specs.
        let args = Args::parse_from(vec![
            "train".into(),
            "--online_adapt".into(),
            "true".into(),
            "--scenario".into(),
            "rank0=step:40:2.5".into(),
            "--adapt_cooldown".into(),
            "30".into(),
        ]);
        let mut o = TrainOptions::default();
        apply_cli_overrides(&mut o, &args).unwrap();
        assert!(o.online_adapt);
        assert_eq!(o.scenario, "rank0=step:40:2.5");
        assert_eq!(o.adapt_cooldown, 30);
    }
}
