//! Minimal CLI argument parser (std-only; no `clap` in the vendored set).
//!
//! Grammar: `kaitian <subcommand> [--key value | --key] [positional...]`.
//! A `--key` followed by another `--...` token (or end of args) is a bare
//! boolean flag with value `"true"`.

use std::collections::BTreeMap;

/// Parsed command-line arguments.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// First positional token (the subcommand), if any.
    pub subcommand: Option<String>,
    /// Remaining positionals.
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse from `std::env::args()` (skipping argv[0]).
    pub fn parse() -> Self {
        Self::parse_from(std::env::args().skip(1).collect())
    }

    /// Parse from an explicit token list (tests).
    pub fn parse_from(tokens: Vec<String>) -> Self {
        let mut out = Args::default();
        let mut i = 0;
        while i < tokens.len() {
            let tok = &tokens[i];
            if let Some(key) = tok.strip_prefix("--") {
                // `--key=value` or `--key value` or bare `--key`.
                if let Some((k, v)) = key.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if i + 1 < tokens.len() && !tokens[i + 1].starts_with("--") {
                    out.flags.insert(key.to_string(), tokens[i + 1].clone());
                    i += 1;
                } else {
                    out.flags.insert(key.to_string(), "true".to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(tok.clone());
            } else {
                out.positional.push(tok.clone());
            }
            i += 1;
        }
        out
    }

    pub fn flag(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn flag_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.flag(key).unwrap_or(default)
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    pub fn usize_flag(&self, key: &str, default: usize) -> crate::Result<usize> {
        match self.flag(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects an integer, got {v:?}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse_from(s.split_whitespace().map(String::from).collect())
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("train --cluster 2G+2M --epochs 5 --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.flag("cluster"), Some("2G+2M"));
        assert_eq!(a.flag("epochs"), Some("5"));
        assert_eq!(a.flag("verbose"), Some("true"));
        assert!(!a.has("missing"));
    }

    #[test]
    fn equals_form() {
        let a = parse("bench --fig=2 --out=results");
        assert_eq!(a.flag("fig"), Some("2"));
        assert_eq!(a.flag("out"), Some("results"));
    }

    #[test]
    fn positionals_collected() {
        let a = parse("probe one two --k v three");
        assert_eq!(a.subcommand.as_deref(), Some("probe"));
        assert_eq!(a.positional, vec!["one", "two", "three"]);
    }

    #[test]
    fn usize_flag_parses_and_errors() {
        let a = parse("x --n 42 --bad abc");
        assert_eq!(a.usize_flag("n", 0).unwrap(), 42);
        assert_eq!(a.usize_flag("missing", 7).unwrap(), 7);
        assert!(a.usize_flag("bad", 0).is_err());
    }
}
