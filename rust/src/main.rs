//! KAITIAN CLI launcher.
//!
//! ```text
//! kaitian train  [--config cfg.json] [--preset P --cluster 2G+2M ...]
//! kaitian serve  [--cluster 2G+2M --policy adaptive --slo_ms 50 --max_batch 8
//!                 --rps 400 --requests 200 --stages 2 --scenario none --out results/]
//! kaitian bench  --fig 2|3|4|micro|all [--out results/] [--quick]
//! kaitian probe  [--cluster 2G+2M] [--preset mobinet]
//! kaitian rendezvous-serve [--addr 127.0.0.1:6379]
//! kaitian worker --rendezvous ADDR --world N  (multi-process demo)
//! ```

use std::collections::BTreeMap;
use std::sync::Arc;

use kaitian::bench::{fig2, fig3, fig4, microbench_collectives};
use kaitian::config::{load_train_options, Args};
use kaitian::perfmodel::PerfModel;
use kaitian::rendezvous::{RendezvousClient, RendezvousServer};
use kaitian::runtime::Engine;
use kaitian::train::train;
use kaitian::Result;

fn main() {
    let args = Args::parse();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("kaitian: error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run(args: &Args) -> Result<()> {
    match args.subcommand.as_deref() {
        Some("train") => cmd_train(args),
        Some("serve") => cmd_serve(args),
        Some("bench") => cmd_bench(args),
        Some("probe") => cmd_probe(args),
        Some("rendezvous-serve") => cmd_rendezvous_serve(args),
        Some("worker") => cmd_worker(args),
        // `--mode=serve` / `--mode=train` aliases for launchers that pass
        // the workload as a flag rather than a subcommand.
        _ if args.flag("mode") == Some("serve") => cmd_serve(args),
        _ if args.flag("mode") == Some("train") => cmd_train(args),
        _ => {
            eprintln!(
                "usage: kaitian <train|serve|bench|probe|rendezvous-serve|worker> [--flags]\n\
                 see README.md for details"
            );
            Ok(())
        }
    }
}

fn artifacts_dir(args: &Args) -> String {
    args.flag_or("artifacts", "artifacts").to_string()
}

fn cmd_train(args: &Args) -> Result<()> {
    let opts = load_train_options(args)?;
    eprintln!(
        "[kaitian] training {} on {} (mode={:?}, strategy={}, grad_sync={}, B={})",
        opts.preset,
        opts.cluster,
        opts.group_mode,
        opts.strategy.name(),
        opts.grad_sync.name(),
        opts.global_batch
    );
    let engine = Arc::new(Engine::load(artifacts_dir(args))?);
    let report = train(engine, &opts)?;
    println!("{}", report.summary());
    println!("scores     = {:?}", report.scores);
    println!("allocation = {:?}", report.allocation);
    if let Some(out) = args.flag("out") {
        std::fs::create_dir_all(out)?;
        let path = format!("{out}/train_{}_{}.json", opts.preset, report.cluster.replace('+', "_"));
        std::fs::write(&path, report.to_json().to_string_pretty())?;
        eprintln!("[kaitian] wrote {path}");
    }
    Ok(())
}

/// Real-time serving run: threads per pipeline stage, wall-clock SLO
/// accounting, `ServeReport` JSON to `--out`.
fn cmd_serve(args: &Args) -> Result<()> {
    use kaitian::serve::{serve, ServeOptions};
    let opts = ServeOptions::from_args(args)?;
    eprintln!(
        "[kaitian] serving on {} (policy={}, slo={}ms, max_batch={}, rps={}, stages={})",
        opts.cluster,
        opts.policy.name(),
        opts.slo_ms,
        opts.max_batch,
        opts.rps,
        opts.stages
    );
    let report = serve(&opts)?;
    println!("{}", report.summary());
    if let Some(out) = args.flag("out") {
        let mut entries = BTreeMap::new();
        entries.insert("serve".to_string(), report.to_json());
        let path = kaitian::metrics::write_report(out, "serving", entries)?;
        eprintln!("[kaitian] wrote {path}");
    }
    Ok(())
}

fn cmd_bench(args: &Args) -> Result<()> {
    let which = args.flag_or("fig", "all");
    let quick = args.has("quick");
    let model = PerfModel::paper_default();
    // Gradient bytes from the real manifest when available, else the
    // calibration constant.
    let grad_bytes = Engine::load(artifacts_dir(args))
        .ok()
        .and_then(|e| e.manifest().program("mobinet").ok().map(|p| p.param_count * 4))
        .unwrap_or(933_544);

    let mut reports = Vec::new();
    if which == "2" || which == "all" {
        reports.push(fig2(&model, grad_bytes)?);
    }
    if which == "3" || which == "all" {
        reports.push(fig3(&model, grad_bytes)?);
    }
    if which == "4" || which == "all" {
        reports.push(fig4(&model, grad_bytes)?);
    }
    if which == "micro" || which == "all" {
        reports.push(microbench_collectives(4, quick)?);
    }
    anyhow::ensure!(!reports.is_empty(), "unknown --fig {which:?} (2|3|4|micro|all)");

    let mut json_all = BTreeMap::new();
    for r in &reports {
        println!("{}\n", r.render());
        json_all.insert(r.id.to_string(), r.json.clone());
    }
    if let Some(out) = args.flag("out") {
        let path = kaitian::metrics::write_report(out, "figures", json_all)?;
        eprintln!("[kaitian] wrote {path}");
    }
    Ok(())
}

fn cmd_probe(args: &Args) -> Result<()> {
    use kaitian::device::{parse_cluster, SpeedModel};
    use kaitian::sched::{proportional_allocation, Profiler};
    let cluster = args.flag_or("cluster", "2G+2M");
    let devices = parse_cluster(cluster)?;
    let profiler = Profiler {
        probe_batch: args.usize_flag("probe-batch", 128)?,
        ..Default::default()
    };
    let scores = profiler.model_scores(&devices, &SpeedModel::paper_default());
    let batch = args.usize_flag("global-batch", 256)?;
    let alloc = proportional_allocation(&scores, batch);
    println!("cluster    = {cluster}");
    for (d, (s, b)) in devices.iter().zip(scores.iter().zip(&alloc)) {
        println!(
            "rank {}  {}  vendor={}  score={s:.3}  batch={b}",
            d.rank,
            d.dtype,
            d.dtype.vendor_lib()
        );
    }
    Ok(())
}

fn cmd_rendezvous_serve(args: &Args) -> Result<()> {
    let addr = args.flag_or("addr", "127.0.0.1:6379");
    let server = RendezvousServer::spawn(addr)?;
    println!("[kaitian] rendezvous serving on {}", server.addr());
    // Serve until killed.
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

/// Multi-process worker demo: discover peers through the rendezvous
/// service, build a real TCP mesh across processes, and verify a
/// collective — the cross-host path of the paper's control plane.
fn cmd_worker(args: &Args) -> Result<()> {
    use kaitian::backend::{CollectiveBackend, GlooHostRelay};
    use kaitian::collectives::{Communicator, ReduceOp};
    use kaitian::transport::TcpEndpoint;
    use std::net::TcpListener;
    use std::time::Duration;

    let rdv_addr: std::net::SocketAddr = args
        .flag("rendezvous")
        .ok_or_else(|| anyhow::anyhow!("--rendezvous host:port required"))?
        .parse()?;
    let world = args.usize_flag("world", 2)?;
    let job = args.flag_or("job", "demo").to_string();

    let mut rdv = RendezvousClient::connect_retry(rdv_addr, 50, Duration::from_millis(100))?;
    // Rank discovery (paper §III-D).
    let rank = (rdv.incr(&format!("{job}:rank"))? - 1) as usize;
    anyhow::ensure!(rank < world, "more workers than --world");

    // Publish our mesh address, collect everyone's.
    let listener = TcpListener::bind("127.0.0.1:0")?;
    rdv.set(&format!("{job}:addr:{rank}"), &listener.local_addr()?.to_string())?;
    let mut addrs = Vec::with_capacity(world);
    for r in 0..world {
        let a = rdv.get_blocking(&format!("{job}:addr:{r}"), Duration::from_secs(30))?;
        addrs.push(a.parse()?);
    }
    rdv.barrier(&format!("{job}:mesh"), world as u64, Duration::from_secs(30))?;

    // Real cross-process TCP mesh + host-relay collective.
    let ep = TcpEndpoint::connect(rank, &addrs, listener)?;
    let relay = GlooHostRelay::new(Communicator::new(Arc::new(ep)));
    let mut buf = vec![(rank + 1) as f32; 1000];
    relay.all_reduce(&mut buf, ReduceOp::Sum)?;
    let expect: f32 = (1..=world).map(|r| r as f32).sum();
    anyhow::ensure!(
        buf.iter().all(|&v| (v - expect).abs() < 1e-5),
        "collective mismatch: got {} want {expect}",
        buf[0]
    );
    println!("[worker {rank}/{world}] all_reduce OK (sum={})", buf[0]);
    rdv.barrier(&format!("{job}:done"), world as u64, Duration::from_secs(30))?;
    Ok(())
}
