//! Async/blocking parity and tag-alignment stress for the non-blocking
//! collective path. Pure rust — no artifacts needed.
//!
//! * The pipelined gradient sync must be *bit-identical* to the blocking
//!   path on heterogeneous clusters (same ring order per bucket → same
//!   float associativity).
//! * Many concurrent `WorkHandle`s on one process group must never
//!   misalign tags across ranks, whatever order the caller waits in.

use kaitian::collectives::ReduceOp;
use kaitian::ddp::DdpEngine;
use kaitian::device::parse_cluster;
use kaitian::group::{build_cluster, ClusterHandles, GroupMode, RelayKind};

fn grads_for(rank: usize, n: usize) -> Vec<f32> {
    (0..n)
        .map(|i| ((i % 97) as f32 - 48.0) * 0.0625 * (rank as f32 + 1.0) + i as f32 * 1e-4)
        .collect()
}

fn run_sync(handles: &ClusterHandles, n: usize, bucket: usize, pipelined: bool) -> Vec<Vec<f32>> {
    std::thread::scope(|s| {
        let hs: Vec<_> = handles
            .groups
            .iter()
            .map(|g| {
                s.spawn(move || {
                    let ddp = DdpEngine::new(g.as_ref(), bucket);
                    let mut grads = grads_for(g.rank(), n);
                    let rep = if pipelined {
                        ddp.all_reduce_grads(&mut grads).unwrap()
                    } else {
                        ddp.all_reduce_grads_blocking(&mut grads).unwrap()
                    };
                    assert!(rep.buckets >= 1);
                    assert!(rep.bytes > 0);
                    grads
                })
            })
            .collect();
        hs.into_iter().map(|h| h.join().unwrap()).collect()
    })
}

#[test]
fn pipelined_grad_sync_bit_identical_to_blocking() {
    for spec in ["1G+2M", "2G+2M"] {
        let devices = parse_cluster(spec).unwrap();
        let n = 50_000;
        let bucket = 16 << 10; // 4096 elems -> ~13 buckets
        let blocking = {
            let handles =
                build_cluster(&devices, RelayKind::Inproc, GroupMode::Kaitian).unwrap();
            run_sync(&handles, n, bucket, false)
        };
        let pipelined = {
            let handles =
                build_cluster(&devices, RelayKind::Inproc, GroupMode::Kaitian).unwrap();
            run_sync(&handles, n, bucket, true)
        };
        assert_eq!(
            blocking, pipelined,
            "{spec}: pipelined sync must be bit-identical to blocking"
        );
        // And all ranks agree with each other.
        for r in 1..pipelined.len() {
            assert_eq!(pipelined[0], pipelined[r], "{spec}: replica divergence");
        }
    }
}

#[test]
fn pipelined_sync_over_tcp_relay_matches_inproc() {
    let devices = parse_cluster("1G+2M").unwrap();
    let n = 10_000;
    let inproc = {
        let handles = build_cluster(&devices, RelayKind::Inproc, GroupMode::Kaitian).unwrap();
        run_sync(&handles, n, 8 << 10, true)
    };
    let tcp = {
        let handles = build_cluster(&devices, RelayKind::Tcp, GroupMode::Kaitian).unwrap();
        run_sync(&handles, n, 8 << 10, true)
    };
    assert_eq!(inproc, tcp, "relay transport must not change numerics");
}

#[test]
fn many_concurrent_work_handles_stay_aligned() {
    // 32 in-flight all-reduces per rank, waited newest-first: execution
    // order across stage threads differs from wait order, but issue-time
    // tag reservation keeps every rank pairing the same logical op.
    let devices = parse_cluster("2G+2M").unwrap();
    let handles = build_cluster(&devices, RelayKind::Inproc, GroupMode::Kaitian).unwrap();
    let world = devices.len();
    const OPS: usize = 32;
    let out: Vec<Vec<Vec<f32>>> = std::thread::scope(|s| {
        let hs: Vec<_> = handles
            .groups
            .iter()
            .map(|g| {
                s.spawn(move || {
                    let mut issued = Vec::new();
                    for k in 0..OPS {
                        // Distinct payload per op and per rank.
                        let buf: Vec<f32> =
                            (0..64).map(|i| (k * 1000 + i) as f32 + g.rank() as f32).collect();
                        issued.push(g.all_reduce_vec_async(buf, ReduceOp::Sum));
                    }
                    let mut results = vec![Vec::new(); OPS];
                    for k in (0..OPS).rev() {
                        let (buf, report) = issued.pop().unwrap().wait().unwrap();
                        assert_eq!(
                            report.path,
                            kaitian::group::CommPath::Hierarchical,
                            "hetero op must take the hierarchical path"
                        );
                        results[k] = buf;
                    }
                    results
                })
            })
            .collect();
        hs.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let rank_sum: f32 = (0..world).map(|r| r as f32).sum();
    for per_rank in &out {
        for (k, buf) in per_rank.iter().enumerate() {
            let expect: Vec<f32> = (0..64)
                .map(|i| world as f32 * (k * 1000 + i) as f32 + rank_sum)
                .collect();
            assert_eq!(buf, &expect, "op {k} misaligned");
        }
    }
}

#[test]
fn interleaved_all_reduce_and_broadcast_handles() {
    // Mixing op kinds in flight must also stay aligned (grad sync +
    // metrics + param broadcast all share the same stage queues).
    let devices = parse_cluster("1G+2M").unwrap();
    let handles = build_cluster(&devices, RelayKind::Inproc, GroupMode::Kaitian).unwrap();
    let out: Vec<(Vec<f32>, Vec<f32>, Vec<f32>)> = std::thread::scope(|s| {
        let hs: Vec<_> = handles
            .groups
            .iter()
            .map(|g| {
                s.spawn(move || {
                    let a = g.all_reduce_vec_async(vec![(g.rank() + 1) as f32; 32], ReduceOp::Sum);
                    let b = g.broadcast_vec_async(
                        if g.rank() == 2 { vec![5.0; 8] } else { vec![0.0; 8] },
                        2,
                    );
                    let c = g.all_reduce_vec_async(vec![2.0; 16], ReduceOp::Max);
                    // Wait in a different order than issued.
                    let (cv, _) = c.wait().unwrap();
                    let (av, _) = a.wait().unwrap();
                    let (bv, _) = b.wait().unwrap();
                    (av, bv, cv)
                })
            })
            .collect();
        hs.into_iter().map(|h| h.join().unwrap()).collect()
    });
    for (a, b, c) in out {
        assert_eq!(a, vec![6.0; 32]); // 1+2+3
        assert_eq!(b, vec![5.0; 8]);
        assert_eq!(c, vec![2.0; 16]);
    }
}

#[test]
fn group_all_gather_matches_communicator_semantics() {
    let devices = parse_cluster("2G+2M").unwrap();
    let handles = build_cluster(&devices, RelayKind::Inproc, GroupMode::Kaitian).unwrap();
    let out: Vec<Vec<f32>> = std::thread::scope(|s| {
        let hs: Vec<_> = handles
            .groups
            .iter()
            .map(|g| {
                s.spawn(move || {
                    let send = vec![g.rank() as f32; 3];
                    let (out, report) = g.all_gather_f32(&send).unwrap();
                    assert!(report.total_bytes() > 0);
                    out
                })
            })
            .collect();
        hs.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let expect: Vec<f32> = (0..4).flat_map(|r| [r as f32; 3]).collect();
    for o in out {
        assert_eq!(o, expect);
    }
}
