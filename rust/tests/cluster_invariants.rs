//! Routing/state invariants of the KAITIAN process group, checked
//! property-style across randomized cluster shapes (DESIGN.md §5).
//! Pure rust — no artifacts needed.

use kaitian::collectives::ReduceOp;
use kaitian::device::{parse_cluster, DeviceSpec, DeviceType};
use kaitian::group::{build_cluster, CommPath, GroupMode, RelayKind};
use kaitian::util::prop::check;
use kaitian::util::Rng;

fn random_cluster(rng: &mut Rng) -> (String, Vec<DeviceSpec>) {
    let g = rng.below(4);
    let m = rng.below(4);
    let (g, m) = if g + m == 0 { (1, 1) } else { (g, m) };
    let spec = match (g, m) {
        (0, m) => format!("{m}M"),
        (g, 0) => format!("{g}G"),
        (g, m) => format!("{g}G+{m}M"),
    };
    let devices = parse_cluster(&spec).unwrap();
    (spec, devices)
}

#[test]
fn prop_all_reduce_sums_correctly_on_any_cluster() {
    check(
        "cluster-allreduce-sum",
        24,
        |rng| {
            let (spec, _) = random_cluster(rng);
            let n = 1 + rng.below(5000);
            (spec, n, rng.next_u64())
        },
        |(spec, n, seed)| {
            let devices = parse_cluster(spec).unwrap();
            let handles =
                build_cluster(&devices, RelayKind::Inproc, GroupMode::Kaitian).unwrap();
            let world = devices.len();
            let out: Vec<Vec<f32>> = std::thread::scope(|s| {
                let hs: Vec<_> = handles
                    .groups
                    .iter()
                    .map(|g| {
                        let (n, seed) = (*n, *seed);
                        s.spawn(move || {
                            let mut rng = Rng::new(seed ^ g.rank() as u64);
                            let buf: Vec<f32> =
                                (0..n).map(|_| (rng.below(100) as f32) / 10.0).collect();
                            let mut out = buf.clone();
                            g.all_reduce(&mut out, ReduceOp::Sum).unwrap();
                            // return both input and output
                            let mut combined = buf;
                            combined.extend_from_slice(&out);
                            combined
                        })
                    })
                    .collect();
                hs.into_iter().map(|h| h.join().unwrap()).collect()
            });
            // Expected sum from each rank's inputs.
            let mut expect = vec![0.0_f32; *n];
            for r in &out {
                for i in 0..*n {
                    expect[i] += r[i];
                }
            }
            for (rank, r) in out.iter().enumerate() {
                for i in 0..*n {
                    let got = r[*n + i];
                    if (got - expect[i]).abs() > 1e-3 * expect[i].abs().max(1.0) {
                        return Err(format!(
                            "{spec} world={world} rank={rank} elem={i}: {got} != {}",
                            expect[i]
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn homogeneous_ops_never_touch_the_relay() {
    for spec in ["1G", "3G", "2M", "4M"] {
        let devices = parse_cluster(spec).unwrap();
        let handles = build_cluster(&devices, RelayKind::Inproc, GroupMode::Kaitian).unwrap();
        let reports: Vec<_> = std::thread::scope(|s| {
            let hs: Vec<_> = handles
                .groups
                .iter()
                .map(|g| {
                    s.spawn(move || {
                        let mut buf = vec![1.0_f32; 100];
                        g.all_reduce(&mut buf, ReduceOp::Sum).unwrap()
                    })
                })
                .collect();
            hs.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for r in reports {
            assert_eq!(r.path, CommPath::Vendor, "{spec}");
            assert_eq!(r.inter.staged_bytes, 0, "{spec}: host staging on vendor path");
            assert_eq!(r.inter.bytes_sent, 0, "{spec}");
        }
    }
}

#[test]
fn heterogeneous_ops_always_stage_on_leaders() {
    for spec in ["1G+1M", "2G+2M", "3G+1M"] {
        let devices = parse_cluster(spec).unwrap();
        let handles = build_cluster(&devices, RelayKind::Inproc, GroupMode::Kaitian).unwrap();
        let reports: Vec<_> = std::thread::scope(|s| {
            let hs: Vec<_> = handles
                .groups
                .iter()
                .map(|g| {
                    s.spawn(move || {
                        let mut buf = vec![1.0_f32; 100];
                        (g.rank(), g.all_reduce(&mut buf, ReduceOp::Sum).unwrap())
                    })
                })
                .collect();
            hs.into_iter().map(|h| h.join().unwrap()).collect()
        });
        let leaders: Vec<usize> = {
            let topo = kaitian::group::Topology::new(devices.clone());
            topo.leaders()
        };
        for (rank, r) in reports {
            assert_eq!(r.path, CommPath::Hierarchical, "{spec}");
            if leaders.contains(&rank) {
                assert!(
                    r.inter.staged_bytes > 0,
                    "{spec}: leader {rank} must stage through host"
                );
            } else {
                assert_eq!(
                    r.inter.staged_bytes, 0,
                    "{spec}: non-leader {rank} must not touch the relay"
                );
            }
        }
    }
}

#[test]
fn mixed_device_letters_group_correctly() {
    // Interleaved ordering: G M G M — groups must still form by type.
    let devices: Vec<DeviceSpec> = vec![
        DeviceSpec::new(0, DeviceType::GpuSim),
        DeviceSpec::new(1, DeviceType::MluSim),
        DeviceSpec::new(2, DeviceType::GpuSim),
        DeviceSpec::new(3, DeviceType::MluSim),
    ];
    let handles = build_cluster(&devices, RelayKind::Inproc, GroupMode::Kaitian).unwrap();
    let out: Vec<Vec<f32>> = std::thread::scope(|s| {
        let hs: Vec<_> = handles
            .groups
            .iter()
            .map(|g| {
                s.spawn(move || {
                    let mut buf = vec![(g.rank() + 1) as f32; 3];
                    g.all_reduce(&mut buf, ReduceOp::Sum).unwrap();
                    buf
                })
            })
            .collect();
        hs.into_iter().map(|h| h.join().unwrap()).collect()
    });
    for o in out {
        assert_eq!(o, vec![10.0; 3]);
    }
}

#[test]
fn repeated_collectives_stay_in_sync() {
    // 50 consecutive mixed ops (all_reduce + broadcast) must not skew tags.
    let devices = parse_cluster("2G+1M").unwrap();
    let handles = build_cluster(&devices, RelayKind::Inproc, GroupMode::Kaitian).unwrap();
    std::thread::scope(|s| {
        for g in &handles.groups {
            s.spawn(move || {
                for i in 0..50 {
                    let mut buf = vec![g.rank() as f32 + 1.0; 17];
                    g.all_reduce(&mut buf, ReduceOp::Sum).unwrap();
                    assert_eq!(buf, vec![6.0; 17], "iteration {i}");
                    let mut b2 = if g.rank() == 1 { vec![i as f32; 5] } else { vec![0.0; 5] };
                    g.broadcast(&mut b2, 1).unwrap();
                    assert_eq!(b2, vec![i as f32; 5], "iteration {i}");
                }
            });
        }
    });
}
