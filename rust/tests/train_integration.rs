//! End-to-end training through the full KAITIAN stack: simulated
//! heterogeneous cluster, load-adaptive split, hierarchical collectives,
//! real PJRT compute, fused Pallas optimizer.
//!
//! Requires `make artifacts-quick` (small presets).

use std::sync::Arc;

use kaitian::group::GroupMode;
use kaitian::runtime::Engine;
use kaitian::sched::Strategy;
use kaitian::train::{train, TrainOptions};

fn engine() -> Option<Arc<Engine>> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: no artifacts — run `make artifacts-quick`");
        return None;
    }
    Some(Arc::new(Engine::load(dir).expect("engine load")))
}

#[test]
fn heterogeneous_training_loss_decreases() {
    let Some(engine) = engine() else { return };
    let mut opts = TrainOptions::quick_test("1G+1M");
    opts.epochs = 4;
    opts.steps_per_epoch = Some(8);
    opts.lr = 0.1;
    let report = train(engine, &opts).unwrap();
    assert_eq!(report.cluster, "1G+1M");
    assert_eq!(report.steps, 32);
    assert_eq!(report.step_losses.len(), 32);
    // Fresh batches each step: compare window means, not endpoints.
    let head: f64 = report.step_losses[..8].iter().sum::<f64>() / 8.0;
    let tail: f64 = report.step_losses[24..].iter().sum::<f64>() / 8.0;
    assert!(
        tail < head,
        "mean loss should fall under SGD: {head:.4} -> {tail:.4}"
    );
    assert!(report.final_accuracy().is_some());
}

#[test]
fn homogeneous_and_heterogeneous_agree_on_gradients() {
    // Same seed + same global batch => same loss trajectory regardless of
    // cluster shape (the DDP-exactness invariant, end to end).
    let Some(engine) = engine() else { return };
    let mut base = TrainOptions::quick_test("1G");
    base.epochs = 1;
    base.steps_per_epoch = Some(4);
    base.eval_batches = 0;
    let solo = train(engine.clone(), &base).unwrap();

    let mut hetero = base.clone();
    hetero.cluster = "1G+1M".into();
    let duo = train(engine.clone(), &hetero).unwrap();

    let mut trio = base.clone();
    trio.cluster = "2G+1M".into();
    let tri = train(engine, &trio).unwrap();

    for (i, ((a, b), c)) in solo
        .step_losses
        .iter()
        .zip(&duo.step_losses)
        .zip(&tri.step_losses)
        .enumerate()
    {
        assert!(
            (a - b).abs() < 1e-3 && (a - c).abs() < 1e-3,
            "step {i}: losses diverge across cluster shapes: {a} {b} {c}"
        );
    }
}

#[test]
fn strategies_change_allocation_not_result() {
    let Some(engine) = engine() else { return };
    let mut opts = TrainOptions::quick_test("1G+1M");
    opts.epochs = 1;
    opts.steps_per_epoch = Some(3);
    opts.eval_batches = 0;
    opts.strategy = Strategy::Equal;
    let equal = train(engine.clone(), &opts).unwrap();
    assert_eq!(equal.allocation, vec![8, 8]);

    opts.strategy = Strategy::Fixed(vec![0.75, 0.25]);
    let fixed = train(engine, &opts).unwrap();
    assert_eq!(fixed.allocation, vec![12, 4]);

    // Same data order => same global gradients => same losses.
    for (a, b) in equal.step_losses.iter().zip(&fixed.step_losses) {
        assert!((a - b).abs() < 1e-3, "strategy must not change numerics");
    }
}

#[test]
fn flat_gloo_mode_trains_identically() {
    let Some(engine) = engine() else { return };
    let mut opts = TrainOptions::quick_test("1G+1M");
    opts.epochs = 1;
    opts.steps_per_epoch = Some(3);
    opts.eval_batches = 0;
    let kaitian = train(engine.clone(), &opts).unwrap();
    opts.group_mode = GroupMode::FlatGloo;
    let flat = train(engine, &opts).unwrap();
    for (a, b) in kaitian.step_losses.iter().zip(&flat.step_losses) {
        assert!((a - b).abs() < 1e-3, "group mode must not change numerics");
    }
}

#[test]
fn native_mode_homogeneous() {
    let Some(engine) = engine() else { return };
    let mut opts = TrainOptions::quick_test("2M");
    opts.group_mode = GroupMode::Native;
    opts.epochs = 1;
    opts.steps_per_epoch = Some(3);
    let report = train(engine, &opts).unwrap();
    assert_eq!(report.group_mode, "native");
    assert_eq!(report.step_losses.len(), 3);
}

#[test]
fn tinygpt_trains_over_hetero_cluster() {
    let Some(engine) = engine() else { return };
    let mut opts = TrainOptions::quick_test("1G+1M");
    opts.preset = "tinygpt_small".into();
    opts.global_batch = 4;
    opts.dataset_len = 64;
    opts.epochs = 2;
    opts.steps_per_epoch = Some(5);
    opts.lr = 0.1;
    let report = train(engine, &opts).unwrap();
    let first = report.step_losses[0];
    let last = *report.step_losses.last().unwrap();
    assert!(last < first, "LM loss should fall: {first} -> {last}");
}

#[test]
fn throttled_profiling_orders_scores_by_device_speed() {
    let Some(engine) = engine() else { return };
    let mut opts = TrainOptions::quick_test("1G+1M");
    opts.throttle = true;
    opts.profile = true;
    opts.epochs = 1;
    opts.steps_per_epoch = Some(2);
    opts.eval_batches = 0;
    let report = train(engine, &opts).unwrap();
    // rank 0 = GPU-sim (throttled slower), rank 1 = MLU-sim (fastest).
    assert!(
        report.scores[1] > report.scores[0],
        "MLU must outscore GPU: {:?}",
        report.scores
    );
    assert!((report.scores[1] - 1.0).abs() < 1e-9);
    // Allocation follows scores.
    assert!(report.allocation[1] > report.allocation[0]);
}

#[test]
fn online_adaptation_corrects_stale_scores() {
    // Paper §V future work: without throttling, all simulated devices are
    // equally fast in reality — but the *initial* (model-derived) scores
    // claim the GPU is ~0.72x. Online adaptation must pull the allocation
    // back toward an even split as measured per-sample times come in.
    let Some(engine) = engine() else { return };
    let mut opts = TrainOptions::quick_test("1G+1M");
    opts.global_batch = 24;
    opts.epochs = 1;
    opts.steps_per_epoch = Some(20);
    opts.eval_batches = 0;
    opts.profile = false; // start from the (wrong, for unthrottled) model scores
    opts.throttle = false;
    opts.online_adapt = true;
    opts.adapt_every = 4;
    let report = train(engine, &opts).unwrap();
    // Initial model scores are [~0.72, 1.0] -> allocation ~[10, 14].
    // Measured equality must pull the final scores together.
    let gap = (report.scores[0] - report.scores[1]).abs();
    assert!(
        gap < 0.28,
        "online adaptation failed to converge scores: {:?}",
        report.scores
    );
    let alloc_gap = (report.allocation[0] as i64 - report.allocation[1] as i64).abs();
    assert!(
        alloc_gap <= 3,
        "allocation still skewed: {:?}",
        report.allocation
    );
}

#[test]
fn fp16_relay_training_matches_uncompressed_closely() {
    // Extension (paper §V-B): fp16 relay compression must not disturb
    // convergence — losses track the exact run within fp16 tolerance.
    let Some(engine) = engine() else { return };
    let mut opts = TrainOptions::quick_test("1G+1M");
    opts.epochs = 1;
    opts.steps_per_epoch = Some(5);
    opts.eval_batches = 0;
    let exact = train(engine.clone(), &opts).unwrap();
    opts.relay = kaitian::group::RelayKind::InprocFp16;
    let fp16 = train(engine, &opts).unwrap();
    for (i, (a, b)) in exact.step_losses.iter().zip(&fp16.step_losses).enumerate() {
        assert!(
            (a - b).abs() < 0.02 * a.abs().max(1.0),
            "step {i}: fp16 diverged: {a} vs {b}"
        );
    }
}

#[test]
fn checkpoint_save_and_resume() {
    let Some(engine) = engine() else { return };
    let dir = std::env::temp_dir().join(format!("kt-resume-{}", std::process::id()));
    let ckpt = dir.join("state.ckpt").to_string_lossy().to_string();

    let mut opts = TrainOptions::quick_test("1G+1M");
    opts.epochs = 1;
    opts.steps_per_epoch = Some(3);
    opts.eval_batches = 0;
    opts.checkpoint = Some(ckpt.clone());
    let first = train(engine.clone(), &opts).unwrap();

    // Resume: loss must continue from (not reset to) the trained state.
    let mut opts2 = opts.clone();
    opts2.checkpoint = None;
    opts2.resume_from = Some(ckpt.clone());
    let resumed = train(engine.clone(), &opts2).unwrap();

    // Fresh run for comparison.
    let mut opts3 = opts.clone();
    opts3.checkpoint = None;
    let fresh = train(engine, &opts3).unwrap();

    assert!(
        resumed.step_losses[0] < fresh.step_losses[0],
        "resumed start {} should beat fresh start {}",
        resumed.step_losses[0],
        fresh.step_losses[0]
    );
    let _ = first;
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn sharded_grad_sync_matches_allreduce_losses() {
    // ISSUE 4 acceptance: ZeRO-1-style sharded gradient sync
    // (reduce-scatter + shard update + parameter all-gather) must track
    // the all-reduce run's losses to <= 1e-5 over 20 steps. The two modes
    // compute the same mathematical update; the only differences are
    // float fold order and the shard-local optimizer arithmetic.
    let Some(engine) = engine() else { return };
    let mut base = TrainOptions::quick_test("2G+2M");
    base.epochs = 4;
    base.steps_per_epoch = Some(5); // 20 steps total
    base.eval_batches = 0;
    let allreduce = train(engine.clone(), &base).unwrap();
    assert_eq!(allreduce.grad_sync, "allreduce");

    let mut sh = base.clone();
    sh.grad_sync = kaitian::ddp::GradSyncMode::Sharded;
    let sharded = train(engine, &sh).unwrap();
    assert_eq!(sharded.grad_sync, "sharded");

    assert_eq!(allreduce.step_losses.len(), 20);
    assert_eq!(sharded.step_losses.len(), 20);
    for (i, (a, b)) in allreduce
        .step_losses
        .iter()
        .zip(&sharded.step_losses)
        .enumerate()
    {
        assert!(
            (a - b).abs() <= 1e-5,
            "step {i}: sharded loss diverged: {a} vs {b}"
        );
    }
}
