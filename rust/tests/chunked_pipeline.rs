//! Multi-chunk streaming through the KaiTian 3-stage pipeline (ISSUE 3
//! tentpole): with `chunk_bytes` forced small, every bucket splits into
//! many chunk slices that flow through the vendor-reduce / host-relay /
//! re-broadcast stage threads independently. The pipelined path must
//! stay bit-identical to the serial blocking path (which walks the same
//! chunk boundaries), and many in-flight chunked ops must never misalign
//! tags across ranks.
//!
//! `chunk_bytes` is process-global, so these tests serialize on a lock
//! and restore the default via an RAII guard (panic-safe).

use std::sync::{Mutex, MutexGuard};

use kaitian::collectives::ReduceOp;
use kaitian::comm::buf::{set_chunk_bytes, DEFAULT_CHUNK_BYTES};
use kaitian::ddp::DdpEngine;
use kaitian::device::parse_cluster;
use kaitian::group::{build_cluster, ClusterHandles, GroupMode, RelayKind};

static SERIAL: Mutex<()> = Mutex::new(());

/// Hold the serialization lock with a small chunk size; restore the
/// default on drop (even on panic).
struct ChunkOverride {
    _lock: MutexGuard<'static, ()>,
}

impl ChunkOverride {
    fn new(bytes: usize) -> Self {
        let lock = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
        set_chunk_bytes(bytes);
        Self { _lock: lock }
    }
}

impl Drop for ChunkOverride {
    fn drop(&mut self) {
        set_chunk_bytes(DEFAULT_CHUNK_BYTES);
    }
}

fn grads_for(rank: usize, n: usize) -> Vec<f32> {
    (0..n)
        .map(|i| ((i % 97) as f32 - 48.0) * 0.0625 * (rank as f32 + 1.0) + i as f32 * 1e-4)
        .collect()
}

fn run_sync(handles: &ClusterHandles, n: usize, bucket: usize, pipelined: bool) -> Vec<Vec<f32>> {
    std::thread::scope(|s| {
        let hs: Vec<_> = handles
            .groups
            .iter()
            .map(|g| {
                s.spawn(move || {
                    let ddp = DdpEngine::new(g.as_ref(), bucket);
                    let mut grads = grads_for(g.rank(), n);
                    let rep = if pipelined {
                        ddp.all_reduce_grads(&mut grads).unwrap()
                    } else {
                        ddp.all_reduce_grads_blocking(&mut grads).unwrap()
                    };
                    assert!(rep.buckets >= 1);
                    grads
                })
            })
            .collect();
        hs.into_iter().map(|h| h.join().unwrap()).collect()
    })
}

#[test]
fn chunked_pipeline_bit_identical_to_blocking() {
    // 1 KiB chunks, 16 KiB buckets: 16 chunk slices stream per bucket.
    let _chunks = ChunkOverride::new(1 << 10);
    for spec in ["1G+2M", "2G+2M"] {
        let devices = parse_cluster(spec).unwrap();
        let n = 30_000;
        let bucket = 16 << 10;
        let blocking = {
            let handles = build_cluster(&devices, RelayKind::Inproc, GroupMode::Kaitian).unwrap();
            run_sync(&handles, n, bucket, false)
        };
        let pipelined = {
            let handles = build_cluster(&devices, RelayKind::Inproc, GroupMode::Kaitian).unwrap();
            run_sync(&handles, n, bucket, true)
        };
        assert_eq!(
            blocking, pipelined,
            "{spec}: chunk-streamed sync must be bit-identical to blocking"
        );
        for r in 1..pipelined.len() {
            assert_eq!(pipelined[0], pipelined[r], "{spec}: replica divergence");
        }
    }
}

#[test]
fn chunked_sync_sums_exactly() {
    // Integer-valued gradients: exact expected sums independent of
    // chunking/association order.
    let _chunks = ChunkOverride::new(512);
    let devices = parse_cluster("1G+2M").unwrap();
    let handles = build_cluster(&devices, RelayKind::Inproc, GroupMode::Kaitian).unwrap();
    let n = 10_000;
    let out: Vec<Vec<f32>> = std::thread::scope(|s| {
        let hs: Vec<_> = handles
            .groups
            .iter()
            .map(|g| {
                s.spawn(move || {
                    let ddp = DdpEngine::new(g.as_ref(), 8 << 10);
                    let mut grads: Vec<f32> =
                        (0..n).map(|i| (i % 17) as f32 * (g.rank() + 1) as f32).collect();
                    let rep = ddp.all_reduce_grads(&mut grads).unwrap();
                    assert!(rep.buckets > 1);
                    grads
                })
            })
            .collect();
        hs.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let expect: Vec<f32> = (0..n).map(|i| (i % 17) as f32 * 6.0).collect();
    for o in out {
        assert_eq!(o, expect);
    }
}

#[test]
fn many_inflight_chunked_ops_stay_aligned() {
    // Several chunked all-reduces in flight, waited newest-first: chunk
    // tags are reserved per chunk at issue time, so interleavings across
    // the stage threads must never pair mismatched chunks.
    let _chunks = ChunkOverride::new(256);
    let devices = parse_cluster("1G+2M").unwrap();
    let handles = build_cluster(&devices, RelayKind::Inproc, GroupMode::Kaitian).unwrap();
    let world = devices.len();
    const OPS: usize = 8;
    let n = 1000; // 256-byte chunks -> ~16 chunks per op
    let out: Vec<Vec<Vec<f32>>> = std::thread::scope(|s| {
        let hs: Vec<_> = handles
            .groups
            .iter()
            .map(|g| {
                s.spawn(move || {
                    let mut issued = Vec::new();
                    for k in 0..OPS {
                        let buf: Vec<f32> =
                            (0..n).map(|i| (k * 100 + i % 50) as f32 + g.rank() as f32).collect();
                        issued.push(g.all_reduce_vec_async(buf, ReduceOp::Sum));
                    }
                    let mut results = vec![Vec::new(); OPS];
                    for k in (0..OPS).rev() {
                        let (buf, _) = issued.pop().unwrap().wait().unwrap();
                        results[k] = buf;
                    }
                    results
                })
            })
            .collect();
        hs.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let rank_sum: f32 = (0..world).map(|r| r as f32).sum();
    for per_rank in &out {
        for (k, buf) in per_rank.iter().enumerate() {
            let expect: Vec<f32> = (0..n)
                .map(|i| world as f32 * (k * 100 + i % 50) as f32 + rank_sum)
                .collect();
            assert_eq!(buf, &expect, "op {k} misaligned");
        }
    }
}
