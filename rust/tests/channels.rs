//! Multi-channel striped transport (ISSUE 10): bitwise parity of the
//! chunked data plane across channel counts.
//!
//! Striping assigns each frame to channel `tag & (MAX_CHUNKS_PER_OP - 1)
//! % channels` — a pure function of the full frame tag — so the
//! tag-addressed mailbox reassembles identical bytes no matter how many
//! sockets the frames rode. These tests pin that invariant: chunked
//! all-reduce, all-to-all, and p2p must be *bit-identical* at 1, 2, and
//! 4 channels (including non-power-of-two worlds and ops with fewer
//! chunks than channels), same-tag streams must stay FIFO, and eager
//! payloads must never leave channel 0.
//!
//! Channel counts are passed explicitly via [`TcpMesh::loopback_with`]
//! so the process-global `KAITIAN_CHANNELS` knob is never touched.

use std::sync::Arc;

use kaitian::collectives::chunk::{self, SubTags};
use kaitian::collectives::ring::ring_all_reduce_chunked;
use kaitian::collectives::{CommStats, Communicator, ReduceOp};
use kaitian::comm::DType;
use kaitian::transport::{TcpEndpoint, TcpMesh, Transport};

/// Run one chunked ring all-reduce per rank (scoped threads) and return
/// each rank's result buffer.
fn all_reduce_mesh(eps: &[TcpEndpoint], n: usize, chunk_bytes: usize) -> Vec<Vec<f32>> {
    std::thread::scope(|s| {
        let hs: Vec<_> = eps
            .iter()
            .map(|ep| {
                s.spawn(move || {
                    // Non-integer values so association order would show
                    // up bitwise if striping ever reordered folds.
                    let mut buf: Vec<f32> = (0..n)
                        .map(|i| {
                            (i % 251) as f32 * 0.1253
                                + (ep.rank() + 1) as f32 * 0.071
                                + i as f32 * 1e-3
                        })
                        .collect();
                    ring_all_reduce_chunked(ep, &mut buf, ReduceOp::Sum, 1 << 20, chunk_bytes)
                        .unwrap();
                    buf
                })
            })
            .collect();
        hs.into_iter().map(|h| h.join().unwrap()).collect()
    })
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

#[test]
fn chunked_all_reduce_bitwise_parity_across_channel_counts() {
    // Worlds include non-powers-of-two; 4 KiB chunks over a 47 KB buffer
    // give every rank a multi-chunk segment to stripe.
    for world in [2, 3, 5] {
        let n = 12_017; // prime-ish length: uneven ring segments
        let cb = 4 << 10;
        let base = all_reduce_mesh(&TcpMesh::loopback_with(world, None, 1).unwrap(), n, cb);
        for nch in [2, 4] {
            let out = all_reduce_mesh(&TcpMesh::loopback_with(world, None, nch).unwrap(), n, cb);
            for (r, (a, b)) in base.iter().zip(&out).enumerate() {
                assert_eq!(
                    bits(a),
                    bits(b),
                    "world {world} rank {r}: {nch}-channel all-reduce diverged from 1-channel"
                );
            }
        }
    }
}

#[test]
fn parity_holds_with_more_channels_than_chunks() {
    // 3 floats across 4 channels: every segment is a single chunk, so
    // most channels carry nothing — striping must degrade gracefully.
    for world in [2, 3] {
        let base = all_reduce_mesh(&TcpMesh::loopback_with(world, None, 1).unwrap(), 3, 4 << 10);
        let out = all_reduce_mesh(&TcpMesh::loopback_with(world, None, 4).unwrap(), 3, 4 << 10);
        for (r, (a, b)) in base.iter().zip(&out).enumerate() {
            assert_eq!(bits(a), bits(b), "world {world} rank {r}: tiny-op divergence");
        }
    }
}

/// Run one tagged all-to-all per rank and return each rank's output.
fn all_to_all_mesh(eps: Vec<TcpEndpoint>, world: usize) -> Vec<Vec<u8>> {
    std::thread::scope(|s| {
        let hs: Vec<_> = eps
            .into_iter()
            .map(|ep| {
                s.spawn(move || {
                    let rank = ep.rank();
                    let comm = Communicator::new(Arc::new(ep) as Arc<dyn Transport>);
                    // `world` segments of 4 KiB each.
                    let n = 1024 * world;
                    let send: Vec<f32> =
                        (0..n).map(|i| (rank * 100_000 + i) as f32 * 0.377).collect();
                    let wire: Vec<u8> = send.iter().flat_map(|x| x.to_le_bytes()).collect();
                    let tag = comm.reserve_tag();
                    let (out, _) = comm.all_to_all_tagged_t(DType::F32, &wire, tag).unwrap();
                    out
                })
            })
            .collect();
        hs.into_iter().map(|h| h.join().unwrap()).collect()
    })
}

#[test]
fn all_to_all_bitwise_parity_across_channel_counts() {
    for world in [3, 4] {
        let base = all_to_all_mesh(TcpMesh::loopback_with(world, None, 1).unwrap(), world);
        for nch in [2, 4] {
            let out = all_to_all_mesh(TcpMesh::loopback_with(world, None, nch).unwrap(), world);
            assert_eq!(
                base, out,
                "world {world}: {nch}-channel all-to-all diverged from 1-channel"
            );
        }
    }
}

#[test]
fn p2p_same_tag_stays_fifo_at_every_channel_count() {
    // 20 sequential 8 KiB messages under ONE full tag, chunked into four
    // 2 KiB frames each. Sub-tags repeat across messages, so ordering
    // relies on per-(peer, tag) FIFO — which striping must preserve by
    // keeping every repeat of a sub-tag on the same channel.
    for nch in [1, 2, 4] {
        let mut eps = TcpMesh::loopback_with(2, None, nch).unwrap();
        let e1 = eps.pop().unwrap();
        let e0 = eps.pop().unwrap();
        let tag = chunk::PTP_TAG_BASE + (7 << chunk::CHUNK_TAG_BITS);
        std::thread::scope(|s| {
            s.spawn(|| {
                for k in 0..20u8 {
                    let msg = vec![k; 8 << 10];
                    let mut tags = SubTags::new(tag);
                    let mut stats = CommStats::default();
                    chunk::send_wire(&e0, 1, &mut tags, &msg, 1, 2 << 10, &mut stats).unwrap();
                }
            });
            s.spawn(|| {
                for k in 0..20u8 {
                    let mut dst = vec![0u8; 8 << 10];
                    let mut tags = SubTags::new(tag);
                    let mut stats = CommStats::default();
                    chunk::recv_place_wire(&e1, 0, &mut tags, &mut dst, 1, 2 << 10, &mut stats)
                        .unwrap();
                    assert!(
                        dst.iter().all(|&b| b == k),
                        "nch {nch}: message {k} arrived out of order"
                    );
                }
            });
        });
    }
}

#[test]
fn p2p_chunked_parity_across_channel_counts() {
    let run = |nch: usize| -> Vec<u8> {
        let mut eps = TcpMesh::loopback_with(2, None, nch).unwrap();
        let e1 = eps.pop().unwrap();
        let e0 = eps.pop().unwrap();
        let tag = chunk::PTP_TAG_BASE + (9 << chunk::CHUNK_TAG_BITS);
        let msg: Vec<u8> = (0..48 * 1024).map(|i| (i % 253) as u8).collect();
        std::thread::scope(|s| {
            let sender = {
                let msg = msg.clone();
                s.spawn(move || {
                    let mut tags = SubTags::new(tag);
                    let mut stats = CommStats::default();
                    chunk::send_wire(&e0, 1, &mut tags, &msg, 1, 4 << 10, &mut stats).unwrap();
                })
            };
            let out = s
                .spawn(move || {
                    let mut dst = vec![0u8; 48 * 1024];
                    let mut tags = SubTags::new(tag);
                    let mut stats = CommStats::default();
                    chunk::recv_place_wire(&e1, 0, &mut tags, &mut dst, 1, 4 << 10, &mut stats)
                        .unwrap();
                    dst
                })
                .join()
                .unwrap();
            sender.join().unwrap();
            out
        })
    };
    let base = run(1);
    for nch in [2, 4] {
        assert_eq!(base, run(nch), "{nch}-channel p2p payload diverged");
    }
}

#[test]
fn eager_payloads_never_stripe() {
    // Payloads ≤ KAITIAN_EAGER_BYTES ride `chunk::send_eager` (a plain
    // `send`), which the transport pins to channel 0 — even when the
    // reserved sub-tags would map to other lanes if striped.
    let eager = kaitian::collectives::algo::eager_bytes();
    let mut eps = TcpMesh::loopback_with(2, None, 4).unwrap();
    let e1 = eps.pop().unwrap();
    let e0 = eps.pop().unwrap();
    let tag = 42 << chunk::CHUNK_TAG_BITS;
    const MSGS: usize = 8;
    std::thread::scope(|s| {
        let h = s.spawn(|| {
            let mut tags = SubTags::new(tag);
            let mut stats = CommStats::default();
            for _ in 0..MSGS {
                let msg = vec![7u8; eager];
                chunk::send_eager(&e0, 1, &mut tags, &msg, &mut stats).unwrap();
            }
        });
        s.spawn(|| {
            let mut tags = SubTags::new(tag);
            let mut stats = CommStats::default();
            for _ in 0..MSGS {
                let mut dst = vec![0u8; eager];
                chunk::recv_eager_place(&e1, 0, &mut tags, &mut dst, &mut stats).unwrap();
                assert!(dst.iter().all(|&b| b == 7));
            }
        });
        h.join().unwrap();
    });
    assert!(
        e0.bytes_sent_on(0) >= (MSGS * eager) as u64,
        "eager traffic should ride channel 0"
    );
    for ch in 1..4 {
        assert_eq!(
            e0.bytes_sent_on(ch),
            0,
            "eager payload leaked onto channel {ch}"
        );
    }
}
