//! Acceptance suite for the size-adaptive collective algorithm engine:
//!
//! * **SPMD alignment** (property test): every rank of a communicator
//!   must select the *identical* algorithm for the same
//!   `(verb, dtype, size, world)` — a divergent pick would pair
//!   mismatched wire programs and deadlock. The engine guarantees this
//!   by agreeing the microprobed α–β table across ranks before any
//!   selection.
//! * **Bitwise parity**: recursive doubling and halving-doubling must
//!   produce byte-identical results to ring across the dtype matrix and
//!   non-power-of-two worlds (exactly-representable values make float
//!   sums order-independent, so any bit difference is a framing or
//!   windowing bug, not rounding).
//! * **Eager path**: the single-inline-frame path must be value- and
//!   byte-identical to the chunked path.

use std::sync::Arc;

use kaitian::collectives::{algo, ring, AlgoPolicy, CommStats, Communicator, ReduceOp};
use kaitian::comm::tensor::{CommTensor, DType};
use kaitian::perfmodel::AlphaBeta;
use kaitian::transport::{InprocMesh, TcpMesh, Transport};
use kaitian::util::prop::check;
use kaitian::Result;

type AlgoFn = fn(&dyn Transport, DType, &mut [u8], ReduceOp, u64, usize) -> Result<CommStats>;

/// Run one all-reduce body on every rank of a fresh inproc mesh and
/// return the per-rank result wire bytes.
fn run(w: usize, dtype: DType, n: usize, chunk: usize, f: AlgoFn) -> Vec<Vec<u8>> {
    let eps = InprocMesh::new(w);
    std::thread::scope(|s| {
        let hs: Vec<_> = eps
            .iter()
            .map(|e| {
                s.spawn(move || {
                    // Values 0..=7 are exactly representable in every
                    // wire dtype (f16/bf16 integers, u8 range, i32), and
                    // their sums across <= 8 ranks stay exact.
                    let vals: Vec<f32> =
                        (0..n).map(|i| ((i + e.rank()) % 8) as f32).collect();
                    let mut t = CommTensor::from_f32(dtype, &vals);
                    f(e, dtype, t.as_bytes_mut(), ReduceOp::Sum, 1 << 16, chunk).unwrap();
                    t.into_wire()
                })
            })
            .collect();
        hs.into_iter().map(|h| h.join().unwrap()).collect()
    })
}

#[test]
fn doubling_and_halving_match_ring_bitwise_across_dtype_matrix() {
    // Worlds include non-powers-of-two (3, 5, 7) — the fold-in/copy-out
    // remainder phases — and sizes both below (53 elems) and above
    // (2500 elems of f32) the default eager threshold.
    for &w in &[2_usize, 3, 4, 5, 7] {
        for &dtype in &[DType::F32, DType::F16, DType::Bf16, DType::I32, DType::U8] {
            for &n in &[1_usize, 53, 2500] {
                let ring_out = run(w, dtype, n, 1 << 16, ring::ring_all_reduce_t);
                let dbl = run(w, dtype, n, 1 << 16, algo::doubling_all_reduce_t);
                assert_eq!(
                    dbl,
                    ring_out,
                    "doubling != ring (w={w} dtype={} n={n})",
                    dtype.name()
                );
                let hd = run(w, dtype, n, 1 << 16, algo::halving_doubling_all_reduce_t);
                assert_eq!(
                    hd,
                    ring_out,
                    "halving-doubling != ring (w={w} dtype={} n={n})",
                    dtype.name()
                );
                let tree = run(w, dtype, n, 1 << 16, algo::tree_all_reduce_t);
                assert_eq!(
                    tree,
                    ring_out,
                    "tree != ring (w={w} dtype={} n={n})",
                    dtype.name()
                );
            }
        }
    }
}

#[test]
fn chunked_and_eager_framings_agree() {
    // 2500 f32 elements stream chunked; tiny chunks force many frames.
    // Both must match the whole-buffer framing bitwise.
    for f in [
        algo::doubling_all_reduce_t as AlgoFn,
        algo::halving_doubling_all_reduce_t as AlgoFn,
    ] {
        let whole = run(5, DType::F32, 2500, 1 << 20, f);
        assert_eq!(run(5, DType::F32, 2500, 128, f), whole);
        // 53 elements ride the eager single-frame path (<= 4 KiB).
        let eager = run(5, DType::F32, 53, 1 << 20, f);
        let expect: Vec<f32> = (0..53)
            .map(|i| (0..5).map(|r| ((i + r) % 8) as f32).sum())
            .collect();
        for wire in &eager {
            let got = kaitian::transport::bytes_to_f32s(wire).unwrap();
            assert_eq!(got, expect);
        }
    }
}

#[test]
fn selection_is_spmd_aligned_property() {
    // Property: for a random (world, elems, dtype), every rank reports
    // the same selected algorithm. The engine microprobes per rank —
    // per-rank timings differ — so this passes only because the probed
    // tables are agreed across ranks before selection.
    check(
        "algo-selection-spmd",
        32,
        |rng| {
            (
                2 + rng.below(5),
                1 + rng.below(4 << 20),
                rng.below(3),
            )
        },
        |&(w, elems, d)| {
            let dtype = [DType::F32, DType::F16, DType::I32][d];
            let comms: Vec<Communicator> = InprocMesh::new(w)
                .into_iter()
                .map(|e| Communicator::new(Arc::new(e)))
                .collect();
            let labels: Vec<&'static str> = std::thread::scope(|s| {
                let hs: Vec<_> = comms
                    .iter()
                    .map(|c| s.spawn(move || c.select_all_reduce(dtype, elems)))
                    .collect();
                hs.into_iter().map(|h| h.join().unwrap()).collect()
            });
            if labels.windows(2).all(|p| p[0] == p[1]) {
                Ok(())
            } else {
                Err(format!("ranks diverged: {labels:?}"))
            }
        },
    );
}

#[test]
fn seeded_engine_matches_pure_selection() {
    // With an explicitly seeded table the engine must reproduce the pure
    // cost-model argmin on every rank — no probe, fully deterministic.
    let ab = AlphaBeta::for_transport_kind("tcp");
    let comms: Vec<Communicator> = InprocMesh::new(4)
        .into_iter()
        .map(|e| Communicator::new(Arc::new(e)))
        .collect();
    for c in &comms {
        c.engine().seed_tuning(ab);
    }
    for elems in [16_usize, 1024, 1 << 20] {
        let expect = algo::choose_with(ab, AlgoPolicy::Adaptive, elems * 4, 4);
        for c in &comms {
            let label = c.select_all_reduce(DType::F32, elems);
            assert!(
                label.starts_with(expect.name()),
                "rank {} picked {label} but the model says {}",
                c.rank(),
                expect.name()
            );
        }
    }
}

#[test]
fn backend_algo_introspection_is_spmd_aligned() {
    // `CollectiveBackend::all_reduce_algo` is the backend-level view of
    // the selection: every rank of a vendor communicator must report
    // the same label, and it must agree with what the dispatched op
    // actually stamps into its stats.
    use kaitian::backend::{CollectiveBackend, VendorKind, VendorSim};
    let backends: Vec<VendorSim> = InprocMesh::new(4)
        .into_iter()
        .map(|e| VendorSim::new(VendorKind::Nccl, Communicator::new(Arc::new(e))))
        .collect();
    let out: Vec<(&'static str, &'static str)> = std::thread::scope(|s| {
        let hs: Vec<_> = backends
            .iter()
            .map(|b| {
                s.spawn(move || {
                    let advertised = b.all_reduce_algo(DType::F32, 256);
                    let mut buf = vec![1.0_f32; 256];
                    let stats = b.all_reduce(&mut buf, ReduceOp::Sum).unwrap();
                    assert_eq!(buf, vec![4.0; 256]);
                    (advertised, stats.algo)
                })
            })
            .collect();
        hs.into_iter().map(|h| h.join().unwrap()).collect()
    });
    for (advertised, stamped) in &out {
        assert_eq!(advertised, &out[0].0, "ranks must advertise one label");
        assert_eq!(
            advertised, stamped,
            "advertised selection must match the executed op's label"
        );
    }
}

#[test]
fn adaptive_all_reduce_is_correct_over_tcp() {
    // End to end over real sockets: whatever the probe decides, the
    // reduced values must be right and identical on every rank, for a
    // latency-bound small message and a bandwidth-bound large one.
    let eps = TcpMesh::loopback(3).unwrap();
    let comms: Vec<Communicator> = eps
        .into_iter()
        .map(|e| Communicator::new(Arc::new(e)))
        .collect();
    for n in [64_usize, 100_000] {
        let out: Vec<(Vec<f32>, &'static str)> = std::thread::scope(|s| {
            let hs: Vec<_> = comms
                .iter()
                .map(|c| {
                    s.spawn(move || {
                        let mut buf: Vec<f32> =
                            (0..n).map(|i| ((i + c.rank()) % 8) as f32).collect();
                        let stats = c.all_reduce(&mut buf, ReduceOp::Sum).unwrap();
                        assert_eq!(stats.op, "all_reduce");
                        assert!(!stats.algo.is_empty());
                        (buf, stats.algo)
                    })
                })
                .collect();
            hs.into_iter().map(|h| h.join().unwrap()).collect()
        });
        let expect: Vec<f32> = (0..n)
            .map(|i| (0..3).map(|r| ((i + r) % 8) as f32).sum())
            .collect();
        for (buf, label) in &out {
            assert_eq!(buf, &expect, "n={n}");
            assert_eq!(label, &out[0].1, "ranks must agree on the algorithm");
        }
    }
}
