//! True multi-process distribution: spawn the `kaitian` binary as a
//! rendezvous server + N worker processes, and verify the cross-process
//! TCP collective completes (the paper's §III-D control plane, end to
//! end, across real process boundaries).

use std::process::{Child, Command, Stdio};
use std::time::Duration;

fn kaitian_bin() -> &'static str {
    env!("CARGO_BIN_EXE_kaitian")
}

struct KillOnDrop(Child);

impl Drop for KillOnDrop {
    fn drop(&mut self) {
        let _ = self.0.kill();
        let _ = self.0.wait();
    }
}

#[test]
fn workers_discover_and_all_reduce_across_processes() {
    // 1. Rendezvous server on a fixed ephemeral-ish port.
    let port = 23791;
    let addr = format!("127.0.0.1:{port}");
    let server = KillOnDrop(
        Command::new(kaitian_bin())
            .args(["rendezvous-serve", "--addr", &addr])
            .stdout(Stdio::null())
            .stderr(Stdio::null())
            .spawn()
            .expect("spawn rendezvous server"),
    );
    std::thread::sleep(Duration::from_millis(300));

    // 2. Three worker processes rendezvous and run a TCP all-reduce.
    let world = 3;
    let workers: Vec<Child> = (0..world)
        .map(|_| {
            Command::new(kaitian_bin())
                .args([
                    "worker",
                    "--rendezvous",
                    &addr,
                    "--world",
                    &world.to_string(),
                    "--job",
                    "itest",
                ])
                .stdout(Stdio::piped())
                .stderr(Stdio::piped())
                .spawn()
                .expect("spawn worker")
        })
        .collect();

    let mut outputs = Vec::new();
    for w in workers {
        let out = w.wait_with_output().expect("wait worker");
        outputs.push(out);
    }
    drop(server);

    for (i, out) in outputs.iter().enumerate() {
        let stdout = String::from_utf8_lossy(&out.stdout);
        let stderr = String::from_utf8_lossy(&out.stderr);
        assert!(
            out.status.success(),
            "worker {i} failed:\nstdout: {stdout}\nstderr: {stderr}"
        );
        assert!(
            stdout.contains("all_reduce OK (sum=6)"),
            "worker {i} wrong result: {stdout}"
        );
    }
}
