//! Integration tests for the inference-serving subsystem (ISSUE 9):
//!
//! * batch-formation properties — no micro-batch ever exceeds
//!   `max_batch`, and no request is held past its SLO-derived batching
//!   budget (randomized arrival streams, virtual time);
//! * pipeline-parallel parity — the staged forward is bitwise-identical
//!   to the single-device forward, through the real threaded pipeline
//!   with activations on the CommTensor p2p wire;
//! * routing re-convergence — under a mid-run load perturbation the
//!   adaptive router lands a rebalance, shifts traffic off the
//!   perturbed replica, and beats static round-robin on p99;
//! * a real-time `serve()` smoke run end to end.

use kaitian::serve::{
    serve, CloseReason, MicroBatch, MicroBatcher, OpenLoopStream, Request, RoutePolicy,
    ServeOptions, StageModel, StagePlan,
};
use kaitian::simnet::{simulate_serve, ServeSimConfig};
use kaitian::util::prop::check;

/// Feed `reqs` through a [`MicroBatcher`] in virtual time, closing
/// budget-expired batches before each later arrival (exactly the event
/// order the server and the simulator use), and drain at the end.
fn form_batches(reqs: &[Request], max_batch: usize, budget_s: f64) -> Vec<MicroBatch> {
    let mut batcher = MicroBatcher::new(max_batch, budget_s);
    let mut out = Vec::new();
    let mut now = 0.0_f64;
    for r in reqs {
        while let Some(d) = batcher.close_deadline() {
            if d > r.arrival_s {
                break;
            }
            now = now.max(d);
            while let Some(b) = batcher.poll(now) {
                out.push(b);
            }
        }
        now = now.max(r.arrival_s);
        batcher.push(*r);
        while let Some(b) = batcher.poll(now) {
            out.push(b);
        }
    }
    while let Some(d) = batcher.close_deadline() {
        now = now.max(d);
        while let Some(b) = batcher.poll(now) {
            out.push(b);
        }
    }
    out
}

#[test]
fn prop_batch_formation_respects_budget_and_capacity() {
    check(
        "serving batch formation",
        60,
        |rng| {
            let n = 1 + rng.below(120);
            let rate = 200.0 + rng.next_f64() * 5800.0;
            let slo_s = 0.005 + rng.next_f64() * 0.045;
            let max_batch = 1 + rng.below(16);
            let budget_s = rng.next_f64() * slo_s;
            let seed = rng.below(1 << 30) as u64;
            (n, rate, slo_s, max_batch, budget_s, seed)
        },
        |&(n, rate, slo_s, max_batch, budget_s, seed)| {
            let reqs: Vec<Request> = OpenLoopStream::new(rate, slo_s, seed).take(n).collect();
            let batches = form_batches(&reqs, max_batch, budget_s);
            let eps = 1e-9;
            for b in &batches {
                if b.is_empty() || b.len() > max_batch {
                    return Err(format!("batch of {} requests (max_batch {max_batch})", b.len()));
                }
                let oldest = b.requests[0];
                // No request waits in the queue past the batching budget.
                if b.formed_s - oldest.arrival_s > budget_s + eps {
                    return Err(format!(
                        "oldest request held {:.6}s > budget {budget_s:.6}s ({:?})",
                        b.formed_s - oldest.arrival_s,
                        b.closed_by
                    ));
                }
                match b.closed_by {
                    // Capacity closes are exactly full.
                    CloseReason::Full if b.len() != max_batch => {
                        return Err(format!("Full close with {} < {max_batch}", b.len()));
                    }
                    // Budget closes never fire early.
                    CloseReason::Budget
                        if b.formed_s + eps < oldest.arrival_s + budget_s =>
                    {
                        return Err(format!(
                            "Budget close at {:.6}s, before {:.6}s",
                            b.formed_s,
                            oldest.arrival_s + budget_s
                        ));
                    }
                    _ => {}
                }
            }
            // Every request batched exactly once, in FIFO order.
            let emitted: Vec<u64> = batches
                .iter()
                .flat_map(|b| b.requests.iter().map(|r| r.id))
                .collect();
            let expect: Vec<u64> = reqs.iter().map(|r| r.id).collect();
            if emitted != expect {
                return Err(format!("order/coverage mismatch: {emitted:?} vs {expect:?}"));
            }
            Ok(())
        },
    );
}

#[test]
fn pipeline_parallel_forward_is_bitwise_identical() {
    let model = StageModel::new(6, 16, 7);
    let inputs: Vec<Vec<f32>> = (0..4).map(|i| model.input(3, 100 + i)).collect();
    let reference: Vec<Vec<f32>> = inputs.iter().map(|x| model.forward(x)).collect();
    for stages in [2_usize, 3] {
        let shares = vec![1.0; stages];
        let plan = StagePlan::balanced(&model.layer_costs(), &shares).unwrap();
        let outs = kaitian::serve::pipeline_forward(&model, &plan, &inputs).unwrap();
        assert_eq!(outs.len(), reference.len());
        for (batch, (a, b)) in reference.iter().zip(&outs).enumerate() {
            assert_eq!(a.len(), b.len());
            for (x, y) in a.iter().zip(b) {
                assert_eq!(
                    x.to_bits(),
                    y.to_bits(),
                    "batch {batch} diverges across {stages} stages"
                );
            }
        }
    }
}

#[test]
fn adaptive_routing_reconverges_under_midrun_perturbation() {
    let run = |policy| {
        let cfg = ServeSimConfig::paper_serving(
            "2G+2M",
            kaitian::device::Scenario::named("step-change").unwrap(),
            policy,
        );
        simulate_serve(&cfg).unwrap()
    };
    let rr = run(RoutePolicy::RoundRobin);
    let ad = run(RoutePolicy::Adaptive);

    assert!(!ad.events.is_empty(), "the perturbation must land a rebalance");
    assert!(
        ad.p99_ms < rr.p99_ms,
        "adaptive p99 {:.2}ms must beat round-robin {:.2}ms",
        ad.p99_ms,
        rr.p99_ms
    );
    // Traffic shifts off the perturbed replica 0 after the first
    // rebalance, but the probe guarantee keeps observing it.
    let first = ad.events[0].step;
    let share = |xs: &[usize]| xs.iter().filter(|&&x| x == 0).count() as f64 / xs.len() as f64;
    let pre = share(&ad.dispatch_replicas[..first]);
    let post = share(&ad.dispatch_replicas[first..]);
    assert!(post < pre, "replica 0 share must fall: pre {pre:.3} post {post:.3}");
    assert!(ad.dispatch_replicas[first..].contains(&0), "probe guarantee");
}

#[test]
fn realtime_serve_completes_all_requests() {
    let opts = ServeOptions {
        cluster: "1G+1M".into(),
        policy: RoutePolicy::Adaptive,
        slo_ms: 50.0,
        max_batch: 4,
        rps: 3000.0,
        requests: 60,
        stages: 2,
        model_layers: 4,
        model_width: 8,
        ..ServeOptions::default()
    };
    let report = serve(&opts).unwrap();
    assert_eq!(report.completed, 60);
    assert_eq!(report.per_replica.len(), 2);
    let hist_requests: usize = report.batch_hist.iter().map(|(n, c)| n * c).sum();
    assert_eq!(hist_requests, 60, "every request in exactly one batch");
    assert!(report.batch_hist.keys().all(|&n| (1..=4).contains(&n)));
    assert!(report.p99_ms >= report.p50_ms);
    assert!(report.throughput_rps > 0.0);
    assert!((0.0..=1.0).contains(&report.violation_rate));
}
