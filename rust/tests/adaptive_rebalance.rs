//! Convergence suite for the dynamic load-adaptive rebalancing subsystem
//! (paper §III-C): deterministic virtual-time scenarios driving the real
//! controller. Pure rust — no artifacts needed. CI also re-runs this
//! suite under `--release`, the profile the adaptive bench uses.

use kaitian::device::{LoadProfile, Scenario};
use kaitian::perfmodel::PerfModel;
use kaitian::sched::{KaitianSampler, Strategy};
use kaitian::simnet::{simulate_dynamic, DynamicSimConfig, DynamicSimReport};
use kaitian::util::prop::check;
use kaitian::util::Rng;

const STEPS: usize = 160;
const CHANGE_AT: usize = 40;

fn step_change_scenario(factor: f64) -> Scenario {
    Scenario::new(
        "step-change",
        vec![(
            0,
            LoadProfile::StepChange {
                at_step: CHANGE_AT,
                factor,
            },
        )],
    )
}

fn run(scenario: Scenario, strategy: Strategy, online: bool) -> DynamicSimReport {
    let mut cfg = DynamicSimConfig::paper_epoch("2G+2M", scenario, online);
    cfg.strategy = strategy;
    cfg.steps = STEPS;
    simulate_dynamic(&PerfModel::paper_default(), &cfg).expect("simulation")
}

/// First step from which the imbalance stays below `bound` to the end.
fn first_stable_step(r: &DynamicSimReport, bound: f64) -> Option<usize> {
    let mut stable_from = None;
    for (s, &imb) in r.imbalance.iter().enumerate() {
        if imb < bound {
            stable_from.get_or_insert(s);
        } else {
            stable_from = None;
        }
    }
    stable_from
}

#[test]
fn step_change_adaptive_reconverges_naive_stays_imbalanced() {
    let adaptive = run(step_change_scenario(2.5), Strategy::Adaptive, true);
    // Strategy A ("naive" equal split) never reacts.
    let naive = run(step_change_scenario(2.5), Strategy::Equal, false);

    // The perturbation bites: right after the change the adaptive run is
    // imbalanced too.
    assert!(
        adaptive.imbalance[CHANGE_AT] > 0.30,
        "step change must disturb the split: {:.3}",
        adaptive.imbalance[CHANGE_AT]
    );
    // ... but the controller re-converges to < 10% step-time imbalance
    // within N = 60 steps, and stays there.
    let stable = first_stable_step(&adaptive, 0.10)
        .expect("adaptive run must re-converge before the end");
    assert!(
        stable <= CHANGE_AT + 60,
        "re-convergence took too long: stable from step {stable}"
    );
    assert!(adaptive.tail_imbalance(20) < 0.10);
    assert!(!adaptive.events.is_empty());

    // The naive split stays imbalanced from the change to the end.
    assert!(naive.events.is_empty());
    assert!(
        naive.imbalance[CHANGE_AT..].iter().all(|&i| i > 0.25),
        "naive equal split must stay imbalanced"
    );
    assert!(
        adaptive.total_s < naive.total_s,
        "adaptive {:.3}s vs naive {:.3}s",
        adaptive.total_s,
        naive.total_s
    );
}

#[test]
fn frozen_adaptive_split_also_stays_imbalanced_after_the_change() {
    // The offline-benchmark split (pre-controller behavior) is good until
    // the perturbation, then permanently bad — the gap the runtime
    // controller closes.
    let frozen = run(step_change_scenario(2.5), Strategy::Adaptive, false);
    assert!(frozen.events.is_empty());
    assert!(frozen.imbalance[CHANGE_AT - 1] < 0.10, "good before the change");
    assert!(
        frozen.imbalance[STEPS - 1] > 0.30,
        "frozen split cannot recover: {:.3}",
        frozen.imbalance[STEPS - 1]
    );
}

#[test]
fn cooldown_guard_spaces_rebalances() {
    let scenario = Scenario::named("thermal-drift").unwrap();
    let cfg = DynamicSimConfig::paper_epoch("2G+2M", scenario, true);
    let r = simulate_dynamic(&PerfModel::paper_default(), &cfg).expect("simulation");
    assert!(
        r.events.len() >= 2,
        "drift must keep triggering rebalances: {} events",
        r.events.len()
    );
    for pair in r.events.windows(2) {
        assert!(
            pair[1].step - pair[0].step >= cfg.controller.cooldown_steps,
            "rebalances at steps {} and {} violate the cooldown {}",
            pair[0].step,
            pair[1].step,
            cfg.controller.cooldown_steps
        );
    }
}

#[test]
fn shift_cap_guard_bounds_every_move() {
    let mut cfg = DynamicSimConfig::paper_epoch("2G+2M", step_change_scenario(2.5), true);
    cfg.steps = STEPS;
    cfg.controller.shift_cap = 8;
    let r = simulate_dynamic(&PerfModel::paper_default(), &cfg).expect("simulation");
    assert!(r.events.len() >= 2, "capped moves need several rebalances");
    for ev in &r.events {
        assert_eq!(ev.new_allocation.iter().sum::<usize>(), cfg.global_batch);
        assert!(ev.new_allocation.iter().all(|&b| b <= cfg.cap));
        let max_shift = ev
            .old_allocation
            .iter()
            .zip(&ev.new_allocation)
            .map(|(&o, &n)| o.abs_diff(n))
            .max()
            .unwrap();
        assert!(
            max_shift <= 8,
            "step {}: allocation jumped by {max_shift} > cap 8",
            ev.step
        );
    }
    // The capped walk still gets there.
    assert!(r.tail_imbalance(20) < 0.15, "{}", r.tail_imbalance(20));
}

#[test]
fn rebalance_frequency_is_bounded_even_under_noise() {
    let cfg = DynamicSimConfig::paper_epoch("2G+2M", Scenario::named("spikes").unwrap(), true);
    let r = simulate_dynamic(&PerfModel::paper_default(), &cfg).expect("simulation");
    let bound = 1 + cfg.steps / cfg.controller.cooldown_steps.max(1);
    assert!(
        r.events.len() <= bound,
        "{} rebalances exceed the cooldown-implied bound {bound}",
        r.events.len()
    );
}

// ---------------------------------------------------------------------
// Sampler correctness across mid-epoch reallocation
// ---------------------------------------------------------------------

#[test]
fn mid_epoch_reallocation_preserves_sampler_correctness() {
    let s = KaitianSampler::new(2048, 64, 9);
    let before = vec![16, 16, 16, 16];
    let after = vec![4, 12, 20, 28]; // a rebalance landed between steps 5 and 6
    let step5 = s.step_indices(0, 5, &before);
    let step6 = s.step_indices(0, 6, &after);

    for (step, alloc) in [(&step5, &before), (&step6, &after)] {
        let all: Vec<usize> = step.iter().flatten().copied().collect();
        assert_eq!(all.len(), 64, "slices must cover exactly the global batch");
        let mut dedup = all.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), 64, "slices within a step must be disjoint");
        let shares: Vec<usize> = step.iter().map(Vec::len).collect();
        assert_eq!(&shares, alloc, "each rank gets exactly its share");
    }

    // Across the allocation change the steps still touch disjoint data.
    let mut union: Vec<usize> = step5
        .iter()
        .chain(step6.iter())
        .flatten()
        .copied()
        .collect();
    assert_eq!(union.len(), 128);
    union.sort_unstable();
    union.dedup();
    assert_eq!(union.len(), 128, "steps must not overlap across a rebalance");
}

#[test]
fn prop_reallocating_every_step_still_covers_the_epoch_exactly() {
    // Random allocation changes at *every* step of an epoch: the union of
    // all per-rank slices must be exactly the dataset, with no index
    // repeated — mid-epoch rebalancing can never corrupt sampling.
    fn random_alloc(rng: &mut Rng, world: usize, batch: usize) -> Vec<usize> {
        let mut cuts: Vec<usize> = (0..world - 1).map(|_| rng.below(batch + 1)).collect();
        cuts.sort_unstable();
        let mut alloc = Vec::with_capacity(world);
        let mut prev = 0;
        for c in cuts {
            alloc.push(c - prev);
            prev = c;
        }
        alloc.push(batch - prev);
        alloc
    }

    check(
        "sampler-realloc-coverage",
        24,
        |rng| {
            let world = 2 + rng.below(4);
            let batch = 8 + rng.below(57);
            let steps = 3 + rng.below(6);
            let allocs: Vec<Vec<usize>> = (0..steps)
                .map(|_| random_alloc(rng, world, batch))
                .collect();
            (batch, allocs, rng.next_u64())
        },
        |(batch, allocs, seed)| {
            let dataset = batch * allocs.len();
            let s = KaitianSampler::new(dataset, *batch, *seed);
            let mut seen = Vec::with_capacity(dataset);
            for (step, alloc) in allocs.iter().enumerate() {
                let per_rank = s.step_indices(0, step, alloc);
                let flat: Vec<usize> = per_rank.iter().flatten().copied().collect();
                if flat.len() != *batch {
                    return Err(format!("step {step}: covered {} != B {batch}", flat.len()));
                }
                seen.extend(flat);
            }
            seen.sort_unstable();
            if seen != (0..dataset).collect::<Vec<_>>() {
                return Err("union of all steps is not the exact dataset".into());
            }
            Ok(())
        },
    );
}
