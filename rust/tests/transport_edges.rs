//! Transport edge cases for the zero-copy data plane (ISSUE 3
//! satellites): zero-length payloads, out-of-order tag delivery,
//! concurrent same-tag chunk interleaving, shutdown waking blocked
//! receivers, and TCP writer-queue backpressure — over both the in-proc
//! and loopback-TCP transports.
//!
//! Plus the lock-free slab mailbox stress suite (ISSUE 6 satellites):
//! racing push/pop/close across shards, generation-tag slot reuse under
//! one-shot tag churn (the ABA hammer), drained-entry reclamation after
//! racing traffic quiesces, and zero-length payloads mixed into
//! contended streams.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use kaitian::comm::buf::Buf;
use kaitian::transport::mailbox::Mailbox;
use kaitian::transport::{InprocMesh, TcpMesh, Transport};

/// Both transports behind one trait object, for shared test bodies.
fn meshes(world: usize) -> Vec<(&'static str, Vec<Box<dyn Transport>>)> {
    let inproc: Vec<Box<dyn Transport>> = InprocMesh::new(world)
        .into_iter()
        .map(|e| Box::new(e) as Box<dyn Transport>)
        .collect();
    let tcp: Vec<Box<dyn Transport>> = TcpMesh::loopback(world)
        .unwrap()
        .into_iter()
        .map(|e| Box::new(e) as Box<dyn Transport>)
        .collect();
    vec![("inproc", inproc), ("tcp", tcp)]
}

#[test]
fn zero_length_payloads_roundtrip() {
    for (kind, eps) in meshes(2) {
        eps[0].send(1, 5, Buf::empty()).unwrap();
        assert!(eps[1].recv(0, 5).unwrap().is_empty(), "{kind}");
        // Zero-length frames between non-empty ones keep framing aligned.
        eps[1].send(0, 6, Buf::copy_from_slice(&[1])).unwrap();
        eps[1].send(0, 6, Buf::empty()).unwrap();
        eps[1].send(0, 6, Buf::copy_from_slice(&[2])).unwrap();
        assert_eq!(eps[0].recv(1, 6).unwrap().as_slice(), &[1_u8][..], "{kind}");
        assert!(eps[0].recv(1, 6).unwrap().is_empty(), "{kind}");
        assert_eq!(eps[0].recv(1, 6).unwrap().as_slice(), &[2_u8][..], "{kind}");
    }
}

#[test]
fn out_of_order_tag_delivery() {
    for (kind, eps) in meshes(2) {
        for tag in [3_u64, 1, 2] {
            eps[0]
                .send(1, tag, Buf::copy_from_slice(&[tag as u8; 4]))
                .unwrap();
        }
        // Receive in a different order than sent: the mailbox parks
        // whatever has not been asked for yet.
        for tag in [1_u64, 2, 3] {
            let got = eps[1].recv(0, tag).unwrap();
            assert_eq!(got.as_slice(), &[tag as u8; 4][..], "{kind} tag {tag}");
        }
    }
}

#[test]
fn concurrent_same_tag_chunk_streams_stay_fifo() {
    // Two chunk streams under different tags, interleaved by one sender,
    // drained concurrently by two receiver threads on the same endpoint:
    // per-(peer, tag) FIFO must hold for both streams.
    const CHUNKS: usize = 200;
    for (kind, eps) in meshes(2) {
        std::thread::scope(|s| {
            let sender = &eps[0];
            s.spawn(move || {
                for i in 0..CHUNKS {
                    sender
                        .send(1, 7, Buf::copy_from_slice(&(i as u32).to_le_bytes()))
                        .unwrap();
                    sender
                        .send(1, 9, Buf::copy_from_slice(&(i as u32 + 1000).to_le_bytes()))
                        .unwrap();
                }
            });
            for (tag, offset) in [(7_u64, 0_u32), (9, 1000)] {
                let receiver = &eps[1];
                s.spawn(move || {
                    for i in 0..CHUNKS {
                        let got = receiver.recv(0, tag).unwrap();
                        let val = u32::from_le_bytes(got.as_slice().try_into().unwrap());
                        assert_eq!(val, i as u32 + offset, "{kind} tag {tag} chunk {i}");
                    }
                });
            }
        });
    }
}

#[test]
fn inproc_shutdown_wakes_all_blocked_receivers() {
    let eps = Arc::new(InprocMesh::new(2));
    let woken = Arc::new(AtomicUsize::new(0));
    let mut handles = Vec::new();
    for (peer, tag) in [(0_usize, 11_u64), (0, 12), (1, 13)] {
        let eps = eps.clone();
        let woken = woken.clone();
        handles.push(std::thread::spawn(move || {
            let err = eps[1].recv(peer, tag).unwrap_err();
            assert!(err.to_string().contains("closed"), "{err}");
            woken.fetch_add(1, Ordering::SeqCst);
        }));
    }
    std::thread::sleep(Duration::from_millis(50));
    eps[1].shutdown();
    for h in handles {
        h.join().unwrap();
    }
    assert_eq!(woken.load(Ordering::SeqCst), 3, "every receiver must wake");
}

#[test]
fn tcp_peer_drop_wakes_blocked_receivers() {
    let mut eps = TcpMesh::loopback(2).unwrap();
    let e1 = eps.pop().unwrap();
    let e0 = eps.pop().unwrap();
    let waiter = std::thread::spawn(move || {
        let t0 = Instant::now();
        let err = e1.recv(0, 42).unwrap_err();
        (t0.elapsed(), err)
    });
    std::thread::sleep(Duration::from_millis(50));
    drop(e0); // sockets close -> e1's reader closes the mailbox
    let (elapsed, err) = waiter.join().unwrap();
    assert!(elapsed < Duration::from_secs(30), "timed out instead of waking");
    let msg = err.to_string();
    assert!(msg.contains("closed") || msg.contains("timeout"), "{msg}");
}

#[test]
fn tcp_writer_cap_bounds_inflight_bytes() {
    // Soft cap 64 KiB, 32 KiB frames: admission control keeps the
    // queued-but-unwritten bytes at or below the cap at all times, and
    // every frame still arrives intact in order.
    const CAP: u64 = 64 << 10;
    const FRAME: usize = 32 << 10;
    const FRAMES: usize = 64;
    let eps = TcpMesh::loopback_with_cap(2, Some(CAP)).unwrap();
    std::thread::scope(|s| {
        let e0 = &eps[0];
        s.spawn(move || {
            for i in 0..FRAMES {
                e0.send(1, 3, Buf::from_vec(vec![i as u8; FRAME])).unwrap();
            }
        });
        let e1 = &eps[1];
        s.spawn(move || {
            // Drain slowly enough that the sender actually races ahead.
            for i in 0..FRAMES {
                let got = e1.recv(0, 3).unwrap();
                assert_eq!(got.len(), FRAME);
                assert_eq!(got.as_slice()[0], i as u8, "frame order broken");
            }
        });
    });
    let hw = eps[0].inflight_high_water();
    assert!(hw > 0, "gauge must have observed traffic");
    assert!(hw <= CAP, "high-water {hw} exceeds the {CAP} soft cap");
}

#[test]
fn mailbox_stress_racing_push_pop_keeps_per_flow_fifo() {
    // 8 threads, each both a producer and a consumer, share one mailbox:
    // thread c consumes flows with f % THREADS == c, pushed by thread
    // (c + 1) % THREADS. Every 5th payload is zero-length, so empty Bufs
    // ride the same contended path. Per-(peer, tag) FIFO must hold for
    // every flow under the full cross-thread race.
    const THREADS: usize = 8;
    const FLOWS: usize = 256; // spans every shard
    const MSGS: usize = 60;
    let mb = Mailbox::new();
    std::thread::scope(|s| {
        for me in 0..THREADS {
            let mb = &mb;
            s.spawn(move || {
                let produce: Vec<u64> = (0..FLOWS as u64)
                    .filter(|f| (*f as usize) % THREADS == (me + THREADS - 1) % THREADS)
                    .collect();
                let consume: Vec<u64> = (0..FLOWS as u64)
                    .filter(|f| (*f as usize) % THREADS == me)
                    .collect();
                let my_peer = (me + 1) % THREADS;
                for seq in 0..MSGS {
                    for &f in &produce {
                        let payload = if seq % 5 == 4 {
                            Buf::empty()
                        } else {
                            let mut b = [0_u8; 8];
                            b[..4].copy_from_slice(&(f as u32).to_le_bytes());
                            b[4..].copy_from_slice(&(seq as u32).to_le_bytes());
                            Buf::copy_from_slice(&b)
                        };
                        mb.push(me, f, payload);
                    }
                    for &f in &consume {
                        let got = mb.pop(my_peer, f, Duration::from_secs(30)).unwrap();
                        if seq % 5 == 4 {
                            assert!(got.is_empty(), "flow {f} seq {seq}: expected empty");
                        } else {
                            let fv = u32::from_le_bytes(got.as_slice()[..4].try_into().unwrap());
                            let sv = u32::from_le_bytes(got.as_slice()[4..].try_into().unwrap());
                            assert_eq!((fv, sv), (f as u32, seq as u32), "FIFO broken on flow {f}");
                        }
                    }
                }
            });
        }
    });
    assert_eq!(mb.pending(), 0, "all messages popped, gauge must be exact at quiescence");
}

#[test]
fn mailbox_stress_one_shot_tags_recycle_slots_under_races() {
    // The ABA hammer: 8 threads burn through 1500 one-shot tags each —
    // every iteration creates a flow, drains it, and reclaims its slot,
    // so arena slots and table entries are recycled thousands of times
    // while other threads race in the same shards. Generation tags must
    // keep every pop matched to its own flow. Each flow is touched by
    // exactly one thread, so reclamation is deterministic: at the end no
    // live flow may remain.
    const THREADS: usize = 8;
    const ITERS: usize = 1500;
    let mb = Mailbox::new();
    std::thread::scope(|s| {
        for t in 0..THREADS {
            let mb = &mb;
            s.spawn(move || {
                for i in 0..ITERS {
                    let tag = (t * ITERS + i) as u64;
                    mb.push(t, tag, Buf::copy_from_slice(&(i as u32).to_le_bytes()));
                    let got = mb.pop(t, tag, Duration::from_secs(30)).unwrap();
                    let v = u32::from_le_bytes(got.as_slice().try_into().unwrap());
                    assert_eq!(v, i as u32, "cross-flow leak via a recycled slot");
                }
            });
        }
    });
    assert_eq!(mb.pending(), 0);
    assert_eq!(
        mb.live_flows(),
        0,
        "single-toucher one-shot flows must all be reclaimed"
    );
}

#[test]
fn mailbox_stress_close_races_with_pushers_and_wakes_waiters() {
    // Receivers parked on flows that never get a message, pushers
    // hammering unrelated flows, and close() landing in the middle:
    // every parked waiter must wake with the "closed" error, never hang.
    let mb = Mailbox::new();
    std::thread::scope(|s| {
        let mut waiters = Vec::new();
        for i in 0..12_u64 {
            let mb = &mb;
            let wait = Duration::from_secs(30);
            waiters.push(s.spawn(move || mb.pop(3, 5000 + i, wait).unwrap_err()));
        }
        for t in 0..4_usize {
            let mb = &mb;
            s.spawn(move || {
                for i in 0..500_u64 {
                    mb.push(t, i % 64, Buf::empty());
                }
            });
        }
        std::thread::sleep(Duration::from_millis(60));
        mb.close();
        for w in waiters {
            let err = w.join().unwrap();
            assert!(err.to_string().contains("closed"), "{err}");
        }
    });
}

#[test]
fn mailbox_stress_shared_flows_reclaim_after_quiesce() {
    // MPMC per flow: 6 pusher threads each push 50 messages into every
    // one of 48 flows while 6 popper threads race to drain them (poppers
    // contend on the same flows, exercising concurrent pop + the
    // REMOVING/rollback reclamation path). Racing reclamation may
    // legitimately leave drained entries live, so after quiescing we
    // drive one sequential push+pop through each flow — that pass must
    // reclaim everything.
    const THREADS: usize = 6;
    const FLOWS: u64 = 48;
    const PER_FLOW: usize = 50;
    let mb = Mailbox::new();
    let popped = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for t in 0..THREADS {
            let mb = &mb;
            s.spawn(move || {
                for seq in 0..PER_FLOW {
                    for f in 0..FLOWS {
                        let payload = if (seq + f as usize) % 2 == 0 {
                            Buf::empty()
                        } else {
                            Buf::copy_from_slice(&[t as u8])
                        };
                        mb.push(9, f, payload);
                    }
                }
            });
        }
        for _ in 0..THREADS {
            let mb = &mb;
            let popped = &popped;
            s.spawn(move || {
                for _ in 0..PER_FLOW {
                    for f in 0..FLOWS {
                        mb.pop(9, f, Duration::from_secs(30)).unwrap();
                        popped.fetch_add(1, Ordering::Relaxed);
                    }
                }
            });
        }
    });
    assert_eq!(popped.load(Ordering::Relaxed), THREADS * FLOWS as usize * PER_FLOW);
    assert_eq!(mb.pending(), 0, "push/pop counts balance, gauge must read zero");
    // Sequential reclamation pass: one message through each flow drains
    // it with a single pin holder, which must retire the entry.
    for f in 0..FLOWS {
        mb.push(9, f, Buf::empty());
        mb.pop(9, f, Duration::from_secs(30)).unwrap();
    }
    assert_eq!(mb.live_flows(), 0, "drained flows must be reclaimed once quiescent");
    assert_eq!(mb.pending(), 0);
}

#[test]
fn tcp_oversize_frame_passes_cap() {
    // A frame larger than the cap is admitted when the queue is empty —
    // the cap must never wedge a link.
    let eps = TcpMesh::loopback_with_cap(2, Some(1024)).unwrap();
    eps[0].send(1, 1, Buf::from_vec(vec![7; 100_000])).unwrap();
    let got = eps[1].recv(0, 1).unwrap();
    assert_eq!(got.len(), 100_000);
    assert!(eps[0].inflight_high_water() >= 100_000);
}
