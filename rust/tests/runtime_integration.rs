//! Integration tests for the PJRT runtime: load the AOT artifacts produced
//! by `make artifacts` (or `make artifacts-quick`) and run real training
//! steps through them.
//!
//! These tests require `artifacts/manifest.json` with the *_small presets;
//! they are skipped (with a loud message) if artifacts are missing so that
//! pure-rust unit tests can run standalone.

use std::sync::Arc;

use kaitian::runtime::{BatchData, Engine, HostTensor, ModelPrograms};
use kaitian::util::Rng;

fn engine() -> Option<Arc<Engine>> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: no artifacts/manifest.json — run `make artifacts-quick`");
        return None;
    }
    Some(Arc::new(Engine::load(dir).expect("engine load")))
}

/// Build a random classification batch for mobinet_small (32x32x3).
fn image_batch(rng: &mut Rng, bucket: usize, real: usize) -> BatchData {
    let n = bucket * 32 * 32 * 3;
    let x: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let y: Vec<i32> = (0..bucket).map(|_| rng.below(10) as i32).collect();
    let mask: Vec<f32> = (0..bucket).map(|i| if i < real { 1.0 } else { 0.0 }).collect();
    BatchData {
        tensors: vec![
            HostTensor::f32(x, &[bucket as i64, 32, 32, 3]),
            HostTensor::i32(y, &[bucket as i64]),
            HostTensor::f32(mask, &[bucket as i64]),
        ],
        real_samples: real,
        bucket,
    }
}

#[test]
fn init_is_deterministic() {
    let Some(engine) = engine() else { return };
    let progs = ModelPrograms::new(engine, "mobinet_small").unwrap();
    let a = progs.init_params(42).unwrap();
    let b = progs.init_params(42).unwrap();
    let c = progs.init_params(7).unwrap();
    assert_eq!(a.len(), progs.param_count());
    assert_eq!(a, b, "same seed must give identical params");
    assert_ne!(a, c, "different seeds must differ");
    assert!(a.iter().all(|v| v.is_finite()));
}

#[test]
fn grad_step_runs_and_is_finite() {
    let Some(engine) = engine() else { return };
    let progs = ModelPrograms::new(engine, "mobinet_small").unwrap();
    let params = progs.init_params(0).unwrap();
    let mut rng = Rng::new(1);
    let batch = image_batch(&mut rng, 4, 4);
    let out = progs.grad_step(&params, &batch).unwrap();
    assert_eq!(out.grads.len(), progs.param_count());
    assert!(out.loss_sum.is_finite() && out.loss_sum > 0.0);
    assert!(out.grads.iter().all(|g| g.is_finite()));
    assert!(out.grads.iter().any(|&g| g != 0.0), "gradients all zero");
}

#[test]
fn masked_padding_is_exact() {
    // Gradients of a bucket with padding == gradients of the bare batch:
    // the mask makes bucketed execution exact, not approximate.
    let Some(engine) = engine() else { return };
    let progs = ModelPrograms::new(engine, "mobinet_small").unwrap();
    let params = progs.init_params(3).unwrap();

    let mut rng = Rng::new(2);
    let small = image_batch(&mut rng, 4, 4); // bucket 4, all real

    // Same 4 real samples, padded into bucket 8 with junk in the tail.
    let mut rng2 = Rng::new(2);
    let b4 = image_batch(&mut rng2, 4, 4);
    let xb4 = b4.tensors[0].as_f32().unwrap().to_vec();
    let mut x8 = xb4.clone();
    x8.extend((0..4 * 32 * 32 * 3).map(|_| 123.0_f32)); // junk padding
    let y8: Vec<i32> = match &b4.tensors[1] {
        HostTensor::I32(d, _) => d.iter().copied().chain([9, 9, 9, 9]).collect(),
        _ => unreachable!(),
    };
    let mask8: Vec<f32> = vec![1.0, 1.0, 1.0, 1.0, 0.0, 0.0, 0.0, 0.0];
    let padded = BatchData {
        tensors: vec![
            HostTensor::f32(x8, &[8, 32, 32, 3]),
            HostTensor::i32(y8, &[8]),
            HostTensor::f32(mask8, &[8]),
        ],
        real_samples: 4,
        bucket: 8,
    };

    let g_small = progs.grad_step(&params, &small).unwrap();
    let g_padded = progs.grad_step(&params, &padded).unwrap();
    assert!(
        (g_small.loss_sum - g_padded.loss_sum).abs() < 1e-3,
        "loss {} vs {}",
        g_small.loss_sum,
        g_padded.loss_sum
    );
    let max_dg = g_small
        .grads
        .iter()
        .zip(&g_padded.grads)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0_f32, f32::max);
    assert!(max_dg < 1e-4, "max grad diff {max_dg}");
}

#[test]
fn sgd_training_reduces_loss() {
    // The real thing: a few full train steps through PJRT must reduce the
    // loss on a fixed batch (overfit test).
    let Some(engine) = engine() else { return };
    let progs = ModelPrograms::new(engine, "mobinet_small").unwrap();
    let mut params = progs.init_params(5).unwrap();
    let mut momentum = vec![0.0_f32; params.len()];
    let mut rng = Rng::new(9);
    let batch = image_batch(&mut rng, 8, 8);

    let first = progs.grad_step(&params, &batch).unwrap();
    let mut last_loss = first.loss_sum;
    let mut g = first.grads;
    for _ in 0..8 {
        // grad_scale = 1/B averages the summed gradients.
        progs
            .apply_update(&mut params, &mut momentum, &g, [0.05, 0.9, 0.0, 1.0 / 8.0])
            .unwrap();
        let out = progs.grad_step(&params, &batch).unwrap();
        last_loss = out.loss_sum;
        g = out.grads;
    }
    assert!(
        last_loss < first.loss_sum * 0.9,
        "loss did not drop: {} -> {}",
        first.loss_sum,
        last_loss
    );
}

#[test]
fn eval_matches_grad_metrics() {
    let Some(engine) = engine() else { return };
    let progs = ModelPrograms::new(engine, "mobinet_small").unwrap();
    let params = progs.init_params(4).unwrap();
    let mut rng = Rng::new(3);
    let batch = image_batch(&mut rng, 4, 3);
    let g = progs.grad_step(&params, &batch).unwrap();
    let (loss, correct) = progs.eval_step(&params, &batch).unwrap();
    assert!((g.loss_sum - loss).abs() < 1e-3);
    assert!((g.correct - correct).abs() < 1e-6);
}

#[test]
fn tinygpt_grad_and_update() {
    let Some(engine) = engine() else { return };
    let progs = ModelPrograms::new(engine, "tinygpt_small").unwrap();
    let mut params = progs.init_params(0).unwrap();
    let mut momentum = vec![0.0_f32; params.len()];
    let mut rng = Rng::new(4);
    let (b, t) = (2_usize, 32_usize);
    let tokens: Vec<i32> = (0..b * t).map(|_| rng.below(256) as i32).collect();
    let batch = BatchData {
        tensors: vec![
            HostTensor::i32(tokens.clone(), &[b as i64, t as i64]),
            HostTensor::i32(tokens, &[b as i64, t as i64]),
            HostTensor::f32(vec![1.0; b], &[b as i64]),
        ],
        real_samples: b,
        bucket: b,
    };
    let first = progs.grad_step(&params, &batch).unwrap();
    assert!(first.loss_sum.is_finite());
    let mut g = first.grads.clone();
    for _ in 0..5 {
        progs
            .apply_update(&mut params, &mut momentum, &g, [0.1, 0.9, 0.0, 1.0 / 2.0])
            .unwrap();
        g = progs.grad_step(&params, &batch).unwrap().grads;
    }
    let last = progs.grad_step(&params, &batch).unwrap();
    assert!(
        last.loss_sum < first.loss_sum,
        "gpt loss did not drop: {} -> {}",
        first.loss_sum,
        last.loss_sum
    );
}
