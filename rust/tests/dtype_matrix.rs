//! The verb × dtype × path acceptance matrix (ISSUE 4).
//!
//! Every collective verb (`all_reduce`, `broadcast`, `all_gather`,
//! `reduce_scatter`, `all_to_all`, `gather`, `send`/`recv`) must
//! round-trip for every [`DType`] over every routing path:
//!
//! * **Vendor** — homogeneous KaiTian cluster ("4G"): vendor library only;
//! * **Hierarchical** — heterogeneous KaiTian cluster ("2G+2M"): vendor
//!   intra-group + leaders over the host relay;
//! * **HostRelay** — FlatGloo over "2G+2M": everything staged through
//!   host memory.
//!
//! For verbs with both forms, the async and blocking paths must agree
//! *bit-identically* (same chunking → same arithmetic). Values are small
//! integers: exactly representable in every dtype (including f16/u8), so
//! expected results are exact regardless of fold order.

use kaitian::collectives::{ring, ReduceOp};
use kaitian::comm::{CommTensor, DType};
use kaitian::device::parse_cluster;
use kaitian::group::{build_cluster, CommPath, GroupMode, ProcessGroup, RelayKind};

/// (cluster spec, group mode, expected routing path) per matrix column.
fn paths() -> Vec<(&'static str, GroupMode, CommPath)> {
    vec![
        ("4G", GroupMode::Kaitian, CommPath::Vendor),
        ("2G+2M", GroupMode::Kaitian, CommPath::Hierarchical),
        ("2G+2M", GroupMode::FlatGloo, CommPath::HostRelay),
    ]
}

/// Rank-dependent small-integer payload (exact in every dtype; sums over
/// 4 ranks stay < 64, inside u8/f16 exact range).
fn values(rank: usize, n: usize) -> Vec<f32> {
    (0..n).map(|i| ((i + 2 * rank) % 13) as f32).collect()
}

/// Run `f` on every rank of a fresh cluster; returns per-rank results.
fn on_cluster<T: Send>(
    spec: &str,
    mode: GroupMode,
    f: impl Fn(&dyn ProcessGroup) -> T + Sync,
) -> Vec<T> {
    let devices = parse_cluster(spec).unwrap();
    let handles = build_cluster(&devices, RelayKind::Inproc, mode).unwrap();
    std::thread::scope(|s| {
        let hs: Vec<_> = handles
            .groups
            .iter()
            .map(|g| {
                let f = &f;
                s.spawn(move || f(g.as_ref()))
            })
            .collect();
        hs.into_iter().map(|h| h.join().unwrap()).collect()
    })
}

#[test]
fn all_reduce_matrix() {
    let n = 97;
    for (spec, mode, path) in paths() {
        for dtype in DType::ALL {
            let out = on_cluster(spec, mode, |g| {
                let init = CommTensor::from_f32(dtype, &values(g.rank(), n));
                let (blocking, rb) = g.all_reduce_t(init.clone(), ReduceOp::Sum).unwrap();
                let (issued, ra) = g.all_reduce_async(init, ReduceOp::Sum).wait().unwrap();
                assert_eq!(rb.path, path, "{spec} {mode:?} {}", dtype.name());
                assert_eq!(ra.path, path);
                assert!(rb.total_bytes() > 0 || g.world() == 1);
                (blocking, issued, g.world(), g.rank())
            });
            let world = out[0].2;
            for (blocking, issued, _, rank) in &out {
                assert_eq!(
                    blocking,
                    issued,
                    "async/blocking parity {spec} {mode:?} {} rank {rank}",
                    dtype.name()
                );
                let got = blocking.to_f32();
                for i in 0..n {
                    let expect: f32 =
                        (0..world).map(|r| ((i + 2 * r) % 13) as f32).sum();
                    assert_eq!(
                        got[i],
                        expect,
                        "{spec} {mode:?} {} elem {i}",
                        dtype.name()
                    );
                }
            }
        }
    }
}

#[test]
fn broadcast_matrix() {
    let n = 33;
    let root = 1;
    for (spec, mode, path) in paths() {
        for dtype in DType::ALL {
            let out = on_cluster(spec, mode, |g| {
                let init = if g.rank() == root {
                    CommTensor::from_f32(dtype, &values(7, n))
                } else {
                    CommTensor::zeros(dtype, n)
                };
                let (blocking, rb) = g.broadcast_t(init.clone(), root).unwrap();
                let (issued, _) = g.broadcast_async(init, root).wait().unwrap();
                assert_eq!(rb.path, path);
                (blocking, issued)
            });
            let expect = CommTensor::from_f32(dtype, &values(7, n));
            for (blocking, issued) in &out {
                assert_eq!(blocking, issued, "{spec} {mode:?} {}", dtype.name());
                assert_eq!(
                    blocking.as_bytes(),
                    expect.as_bytes(),
                    "{spec} {mode:?} {}",
                    dtype.name()
                );
            }
        }
    }
}

#[test]
fn all_gather_matrix() {
    let n = 5;
    for (spec, mode, path) in paths() {
        for dtype in DType::ALL {
            let out = on_cluster(spec, mode, |g| {
                let send = CommTensor::from_f32(dtype, &values(g.rank(), n));
                let (a, ra) = g.all_gather(&send).unwrap();
                let (b, _) = g.all_gather(&send).unwrap();
                assert_eq!(ra.path, path);
                (a, b, g.world())
            });
            let world = out[0].2;
            let expect: Vec<f32> = (0..world).flat_map(|r| values(r, n)).collect();
            let expect = CommTensor::from_f32(dtype, &expect);
            for (a, b, _) in &out {
                assert_eq!(a, b, "deterministic {spec} {mode:?} {}", dtype.name());
                assert_eq!(a.as_bytes(), expect.as_bytes(), "{spec} {mode:?} {}", dtype.name());
            }
        }
    }
}

#[test]
fn reduce_scatter_matrix() {
    let n = 103; // uneven segments across 4 ranks
    for (spec, mode, path) in paths() {
        for dtype in DType::ALL {
            let out = on_cluster(spec, mode, |g| {
                let init = CommTensor::from_f32(dtype, &values(g.rank(), n));
                let (blocking, rb) = g.reduce_scatter(init.clone(), ReduceOp::Sum).unwrap();
                let (issued, _) = g.reduce_scatter_async(init, ReduceOp::Sum).wait().unwrap();
                assert_eq!(rb.path, path);
                (blocking, issued, g.world(), g.rank())
            });
            let world = out[0].2;
            for (blocking, issued, _, rank) in &out {
                assert_eq!(blocking, issued, "{spec} {mode:?} {}", dtype.name());
                let (s0, s1) = ring::segment(n, world, *rank);
                assert_eq!(blocking.len(), s1 - s0, "shard length rank {rank}");
                let got = blocking.to_f32();
                for (j, i) in (s0..s1).enumerate() {
                    let expect: f32 =
                        (0..world).map(|r| ((i + 2 * r) % 13) as f32).sum();
                    assert_eq!(
                        got[j],
                        expect,
                        "{spec} {mode:?} {} rank {rank} elem {i}",
                        dtype.name()
                    );
                }
            }
        }
    }
}

#[test]
fn all_to_all_matrix() {
    for (spec, mode, path) in paths() {
        for dtype in DType::ALL {
            let out = on_cluster(spec, mode, |g| {
                let w = g.world();
                let n = w * 3;
                // Segment j of rank r carries marker (r, j).
                let send: Vec<f32> = (0..n)
                    .map(|i| ((g.rank() * w + i / 3) % 13) as f32)
                    .collect();
                let send = CommTensor::from_f32(dtype, &send);
                let (blocking, rb) = g.all_to_all(send.clone()).unwrap();
                let (issued, _) = g.all_to_all_async(send).wait().unwrap();
                assert_eq!(rb.path, path);
                (blocking, issued, w, g.rank())
            });
            for (blocking, issued, w, rank) in &out {
                assert_eq!(blocking, issued, "{spec} {mode:?} {}", dtype.name());
                let got = blocking.to_f32();
                for j in 0..*w {
                    // Output segment j came from rank j's segment `rank`.
                    let expect = ((j * w + rank) % 13) as f32;
                    for k in 0..3 {
                        assert_eq!(
                            got[j * 3 + k],
                            expect,
                            "{spec} {mode:?} {} out-seg {j}",
                            dtype.name()
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn gather_matrix() {
    let n = 4;
    for (spec, mode, path) in paths() {
        // Exercise a leader root (0) and, on the heterogeneous clusters,
        // a non-leader root (3 is the second rank of the MLU group).
        for root in [0_usize, 3] {
            for dtype in DType::ALL {
                let out = on_cluster(spec, mode, |g| {
                    let send = CommTensor::from_f32(dtype, &values(g.rank(), n));
                    let (a, ra) = g.gather(&send, root).unwrap();
                    let (b, _) = g.gather(&send, root).unwrap();
                    assert_eq!(ra.path, path);
                    (a, b, g.world(), g.rank())
                });
                let world = out[0].2;
                let expect: Vec<f32> = (0..world).flat_map(|r| values(r, n)).collect();
                let expect = CommTensor::from_f32(dtype, &expect);
                for (a, b, _, rank) in &out {
                    assert_eq!(a, b, "deterministic {spec} {mode:?} {}", dtype.name());
                    if *rank == root {
                        let a = a.as_ref().expect("root receives the gather");
                        assert_eq!(
                            a.as_bytes(),
                            expect.as_bytes(),
                            "{spec} {mode:?} {} root {root}",
                            dtype.name()
                        );
                    } else {
                        assert!(a.is_none(), "non-root rank {rank} gets None");
                    }
                }
            }
        }
    }
}

#[test]
fn point_to_point_matrix() {
    let n = 19;
    for (spec, mode, _path) in paths() {
        for (di, dtype) in DType::ALL.iter().enumerate() {
            let dtype = *dtype;
            let out = on_cluster(spec, mode, |g| {
                let w = g.world();
                let me = g.rank();
                // Ring exchange: send to next, receive from prev.
                let payload = CommTensor::from_f32(dtype, &values(me, n));
                g.send(&payload, (me + 1) % w, di as u32).unwrap();
                let (got, report) = g
                    .recv(dtype, n, (me + w - 1) % w, di as u32)
                    .unwrap();
                // Routing invariant: cross-group p2p must not be Vendor.
                let prev = (me + w - 1) % w;
                (got, report.path, me, prev)
            });
            for (got, rpath, me, prev) in &out {
                let expect = CommTensor::from_f32(dtype, &values(*prev, n));
                assert_eq!(
                    got.as_bytes(),
                    expect.as_bytes(),
                    "{spec} {mode:?} {} rank {me}",
                    dtype.name()
                );
                assert!(
                    matches!(rpath, CommPath::Vendor | CommPath::HostRelay),
                    "p2p reports a concrete path"
                );
            }
        }
    }
}

#[test]
fn min_max_parity_through_hierarchical_and_host_relay() {
    // Satellite: Min/Max were only exercised end-to-end under Sum before;
    // drive both through the Hierarchical and HostRelay paths and check
    // exact extrema (and async/blocking agreement).
    let n = 257;
    for (spec, mode, path) in [
        ("2G+2M", GroupMode::Kaitian, CommPath::Hierarchical),
        ("2G+2M", GroupMode::FlatGloo, CommPath::HostRelay),
    ] {
        for op in [ReduceOp::Min, ReduceOp::Max] {
            let out = on_cluster(spec, mode, |g| {
                let init: Vec<f32> = (0..n)
                    .map(|i| (i as f32) * if g.rank() % 2 == 0 { 1.0 } else { -1.0 }
                        + g.rank() as f32)
                    .collect();
                let mut blocking = init.clone();
                let rb = g.all_reduce(&mut blocking, op).unwrap();
                assert_eq!(rb.path, path);
                let (issued, _) = g
                    .all_reduce_async(CommTensor::from_vec(init), op)
                    .wait()
                    .unwrap();
                (blocking, issued.into_vec().unwrap(), g.world())
            });
            let world = out[0].2;
            for (blocking, issued, _) in &out {
                assert_eq!(blocking, issued, "{mode:?} {}", op.name());
                for i in 0..n {
                    let per_rank: Vec<f32> = (0..world)
                        .map(|r| (i as f32) * if r % 2 == 0 { 1.0 } else { -1.0 } + r as f32)
                        .collect();
                    let expect = match op {
                        ReduceOp::Max => per_rank.iter().cloned().fold(f32::MIN, f32::max),
                        _ => per_rank.iter().cloned().fold(f32::MAX, f32::min),
                    };
                    assert_eq!(blocking[i], expect, "{mode:?} {} elem {i}", op.name());
                }
            }
        }
    }
}

#[test]
fn min_max_dtyped_through_both_paths() {
    // Min/Max parity for the narrow dtypes too (f16/bf16/i32/u8 folds are
    // dtype-native).
    let n = 64;
    for (spec, mode, _path) in [
        ("2G+2M", GroupMode::Kaitian, CommPath::Hierarchical),
        ("2G+2M", GroupMode::FlatGloo, CommPath::HostRelay),
    ] {
        for dtype in [DType::F16, DType::Bf16, DType::I32, DType::U8] {
            for op in [ReduceOp::Min, ReduceOp::Max] {
                let out = on_cluster(spec, mode, |g| {
                    let init = CommTensor::from_f32(dtype, &values(g.rank(), n));
                    let (blocking, _) = g.all_reduce_t(init.clone(), op).unwrap();
                    let (issued, _) = g.all_reduce_async(init, op).wait().unwrap();
                    (blocking, issued, g.world())
                });
                let world = out[0].2;
                for (blocking, issued, _) in &out {
                    assert_eq!(blocking, issued, "{mode:?} {} {}", dtype.name(), op.name());
                    let got = blocking.to_f32();
                    for i in 0..n {
                        let per_rank: Vec<f32> =
                            (0..world).map(|r| ((i + 2 * r) % 13) as f32).collect();
                        let expect = match op {
                            ReduceOp::Max => per_rank.iter().cloned().fold(f32::MIN, f32::max),
                            _ => per_rank.iter().cloned().fold(f32::MAX, f32::min),
                        };
                        assert_eq!(
                            got[i],
                            expect,
                            "{mode:?} {} {} elem {i}",
                            dtype.name(),
                            op.name()
                        );
                    }
                }
            }
        }
    }
}
