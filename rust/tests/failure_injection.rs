//! Failure injection: dead peers, closed meshes, barrier timeouts and
//! simulated OOM must produce *errors*, never hangs.
//!
//! Uses `KAITIAN_RECV_TIMEOUT_MS` to keep timeouts test-sized. Since the
//! env var is cached process-wide, every test in this binary runs with
//! the short timeout.

use std::time::Duration;

use kaitian::backend::{CollectiveBackend, GlooHostRelay, VendorKind, VendorSim};
use kaitian::collectives::{Communicator, ReduceOp};
use kaitian::device::MemoryTracker;
use kaitian::rendezvous::{RendezvousClient, RendezvousServer};
use kaitian::transport::{InprocMesh, TcpMesh};
use std::sync::Arc;

fn set_short_timeout() {
    // Must run before the first recv (OnceLock caches it).
    std::env::set_var("KAITIAN_RECV_TIMEOUT_MS", "500");
}

#[test]
fn dead_peer_times_out_instead_of_hanging() {
    set_short_timeout();
    let mut eps = InprocMesh::new(2);
    let _dead = eps.pop().unwrap(); // rank 1 never participates
    let e0 = eps.pop().unwrap();
    let comm = Communicator::new(Arc::new(e0));
    let backend = VendorSim::new(VendorKind::Nccl, comm);
    let mut buf = vec![1.0_f32; 64];
    let t0 = std::time::Instant::now();
    let err = backend.all_reduce(&mut buf, ReduceOp::Sum).unwrap_err();
    assert!(t0.elapsed() < Duration::from_secs(10), "did not time out promptly");
    assert!(err.to_string().contains("timeout"), "{err}");
}

#[test]
fn tcp_peer_disconnect_unblocks_receivers() {
    set_short_timeout();
    let mut eps = TcpMesh::loopback(2).unwrap();
    let e1 = eps.pop().unwrap();
    let e0 = eps.pop().unwrap();
    // Kill rank 1's endpoint entirely: its sockets close.
    drop(e1);
    let comm = Communicator::new(Arc::new(e0));
    let relay = GlooHostRelay::new(comm);
    let mut buf = vec![0.0_f32; 1024];
    let err = relay.all_reduce(&mut buf, ReduceOp::Sum).unwrap_err();
    let msg = err.to_string();
    assert!(
        msg.contains("closed") || msg.contains("timeout"),
        "unexpected error: {msg}"
    );
}

#[test]
fn rendezvous_barrier_underflow_times_out() {
    let server = RendezvousServer::spawn("127.0.0.1:0").unwrap();
    let mut c = RendezvousClient::connect(server.addr()).unwrap();
    let err = c
        .barrier("missing-peers", 3, Duration::from_millis(200))
        .unwrap_err();
    assert!(err.to_string().contains("timeout"), "{err}");
    server.shutdown();
}

#[test]
fn rendezvous_server_shutdown_breaks_clients_cleanly() {
    let server = RendezvousServer::spawn("127.0.0.1:0").unwrap();
    let addr = server.addr();
    let mut c = RendezvousClient::connect(addr).unwrap();
    c.set("x", "1").unwrap();
    server.shutdown();
    // Further connections must fail (not hang).
    let res = RendezvousClient::connect_retry(addr, 2, Duration::from_millis(50));
    if let Ok(mut c2) = res {
        // Accept loop is gone; an op should error once the socket dies.
        let _ = c2.ping(); // either way, must return
    }
}

#[test]
fn simulated_oom_fails_allocation_not_process() {
    // A GTX-1080-class card (8 GiB) cannot hold a 10 GiB tensor.
    let vram = MemoryTracker::new(8 << 30);
    vram.alloc(6 << 30).unwrap();
    let err = vram.alloc(4 << 30).unwrap_err();
    assert!(err.to_string().contains("OOM"));
    // Accounting is intact afterwards.
    assert_eq!(vram.used(), 6 << 30);
    vram.free(6 << 30);
    assert_eq!(vram.used(), 0);
}

#[test]
fn batch_bigger_than_buckets_is_a_clean_error() {
    // The trainer guards this via cap_allocation: a global batch that
    // cannot fit devices*max_bucket must error with guidance, not hang.
    let err = kaitian::sched::cap_allocation(&[40, 40], 16).unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("cannot fit"), "{msg}");
    assert!(msg.contains("global batch"), "{msg}");
}
