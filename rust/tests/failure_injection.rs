//! Failure injection: dead peers, closed meshes, barrier timeouts and
//! simulated OOM must produce *errors*, never hangs.
//!
//! Uses `KAITIAN_RECV_TIMEOUT_MS` to keep timeouts test-sized. Since the
//! env var is cached process-wide, every test in this binary runs with
//! the short timeout.

use std::time::Duration;

use kaitian::backend::{CollectiveBackend, GlooHostRelay, VendorKind, VendorSim};
use kaitian::collectives::{Communicator, ReduceOp};
use kaitian::comm::buf::Buf;
use kaitian::device::MemoryTracker;
use kaitian::rendezvous::{RendezvousClient, RendezvousServer};
use kaitian::train::{train_elastic, ElasticConfig, FaultSpec};
use kaitian::transport::mailbox::Mailbox;
use kaitian::transport::{InprocMesh, TcpEndpoint, TcpMesh, Transport};
use std::sync::Arc;

fn set_short_timeout() {
    // Must run before the first recv (OnceLock caches it).
    std::env::set_var("KAITIAN_RECV_TIMEOUT_MS", "500");
}

#[test]
fn dead_peer_times_out_instead_of_hanging() {
    set_short_timeout();
    let mut eps = InprocMesh::new(2);
    let _dead = eps.pop().unwrap(); // rank 1 never participates
    let e0 = eps.pop().unwrap();
    let comm = Communicator::new(Arc::new(e0));
    let backend = VendorSim::new(VendorKind::Nccl, comm);
    let mut buf = vec![1.0_f32; 64];
    let t0 = std::time::Instant::now();
    let err = backend.all_reduce(&mut buf, ReduceOp::Sum).unwrap_err();
    assert!(t0.elapsed() < Duration::from_secs(10), "did not time out promptly");
    assert!(err.to_string().contains("timeout"), "{err}");
}

#[test]
fn tcp_peer_disconnect_unblocks_receivers() {
    set_short_timeout();
    let mut eps = TcpMesh::loopback(2).unwrap();
    let e1 = eps.pop().unwrap();
    let e0 = eps.pop().unwrap();
    // Kill rank 1's endpoint entirely: its sockets close.
    drop(e1);
    let comm = Communicator::new(Arc::new(e0));
    let relay = GlooHostRelay::new(comm);
    let mut buf = vec![0.0_f32; 1024];
    let err = relay.all_reduce(&mut buf, ReduceOp::Sum).unwrap_err();
    let msg = err.to_string();
    assert!(
        msg.contains("peer 1 lost") || msg.contains("closed") || msg.contains("timeout"),
        "unexpected error: {msg}"
    );
}

#[test]
fn rendezvous_barrier_underflow_times_out() {
    let server = RendezvousServer::spawn("127.0.0.1:0").unwrap();
    let mut c = RendezvousClient::connect(server.addr()).unwrap();
    let err = c
        .barrier("missing-peers", 3, Duration::from_millis(200))
        .unwrap_err();
    assert!(err.to_string().contains("timeout"), "{err}");
    server.shutdown();
}

#[test]
fn rendezvous_server_shutdown_breaks_clients_cleanly() {
    let server = RendezvousServer::spawn("127.0.0.1:0").unwrap();
    let addr = server.addr();
    let mut c = RendezvousClient::connect(addr).unwrap();
    c.set("x", "1").unwrap();
    server.shutdown();
    // Further connections must fail (not hang).
    let res = RendezvousClient::connect_retry(addr, 2, Duration::from_millis(50));
    if let Ok(mut c2) = res {
        // Accept loop is gone; an op should error once the socket dies.
        let _ = c2.ping(); // either way, must return
    }
}

#[test]
fn simulated_oom_fails_allocation_not_process() {
    // A GTX-1080-class card (8 GiB) cannot hold a 10 GiB tensor.
    let vram = MemoryTracker::new(8 << 30);
    vram.alloc(6 << 30).unwrap();
    let err = vram.alloc(4 << 30).unwrap_err();
    assert!(err.to_string().contains("OOM"));
    // Accounting is intact afterwards.
    assert_eq!(vram.used(), 6 << 30);
    vram.free(6 << 30);
    assert_eq!(vram.used(), 0);
}

#[test]
fn close_peer_races_with_concurrent_pushers() {
    // close_peer(0) racing against pushers and parked receivers on both
    // peers: peer 1's flows must be completely untouched (every pop
    // succeeds), while peer-0 pops either deliver (drain-first) or fail
    // with the per-peer error — never "mailbox closed", never a hang.
    set_short_timeout();
    const TAGS: u64 = 50;
    for _round in 0..10 {
        let mb = Arc::new(Mailbox::new());
        std::thread::scope(|s| {
            for peer in 0..2_usize {
                let mb = mb.clone();
                s.spawn(move || {
                    for tag in 0..TAGS {
                        mb.push(peer, tag, Buf::copy_from_slice(&[peer as u8]));
                    }
                });
            }
            let closer = mb.clone();
            s.spawn(move || closer.close_peer(0));
            let healthy = mb.clone();
            s.spawn(move || {
                for tag in 0..TAGS {
                    let got = healthy.pop(1, tag, Duration::from_secs(10)).unwrap();
                    assert_eq!(got, vec![1_u8]);
                }
            });
            let failed = mb.clone();
            s.spawn(move || {
                for tag in 0..TAGS {
                    match failed.pop(0, tag, Duration::from_secs(10)) {
                        Ok(got) => assert_eq!(got, vec![0_u8]),
                        Err(e) => {
                            assert!(e.to_string().contains("peer 0 lost"), "{e}")
                        }
                    }
                }
            });
        });
        assert!(mb.peer_dead(0));
        assert!(!mb.peer_dead(1));
    }
}

#[test]
fn oversized_wire_length_fails_peer_not_allocator() {
    // A hostile/corrupt frame header claiming u64::MAX payload bytes
    // must fail that link (per-peer, promptly) instead of reaching the
    // buffer pool as a near-unbounded allocation.
    set_short_timeout();
    use std::io::{Read as _, Write as _};
    use std::net::TcpListener;
    let l0 = TcpListener::bind("127.0.0.1:0").unwrap();
    let l1 = TcpListener::bind("127.0.0.1:0").unwrap();
    let addrs = vec![l0.local_addr().unwrap(), l1.local_addr().unwrap()];
    // "Rank 1" is a raw socket, not a TcpEndpoint: it accepts rank 0's
    // dial, reads the 8-byte rank announcement, then sends a poisoned
    // 24-byte header ([tag][epoch][len]) and holds the socket open so
    // EOF cannot be what unblocks the victim.
    let attacker = std::thread::spawn(move || {
        let (mut s, _) = l1.accept().unwrap();
        let mut id = [0_u8; 8];
        s.read_exact(&mut id).unwrap();
        assert_eq!(u64::from_le_bytes(id), 0, "victim announces rank 0");
        let mut hdr = Vec::with_capacity(24);
        hdr.extend_from_slice(&7_u64.to_le_bytes());
        hdr.extend_from_slice(&0_u64.to_le_bytes());
        hdr.extend_from_slice(&u64::MAX.to_le_bytes());
        s.write_all(&hdr).unwrap();
        s.flush().unwrap();
        s
    });
    let e0 = TcpEndpoint::connect(0, &addrs, l0).unwrap();
    let _open_socket = attacker.join().unwrap();
    let t0 = std::time::Instant::now();
    let err = e0.recv(1, 7).unwrap_err();
    assert!(err.to_string().contains("peer 1 lost"), "{err}");
    assert!(
        t0.elapsed() < Duration::from_secs(10),
        "poisoned header must fail fast"
    );
}

#[test]
fn mid_training_rank_death_recovers() {
    // The tentpole lifecycle end to end: rank 1 dies mid-segment, the
    // heartbeat monitor detects the expired lease, survivors abort,
    // bump the epoch, regroup as a 2-rank world, resume from the
    // last segment checkpoint, and still converge.
    set_short_timeout();
    let mut cfg = ElasticConfig::quick("1G+2M");
    cfg.fault = Some(FaultSpec {
        rank: 1,
        at_step: 9,
        rejoin_after_segments: 0,
    });
    let report = train_elastic(&cfg).unwrap();
    let rec = report
        .recovery
        .as_ref()
        .expect("rank death must be detected and recovered from");
    assert_eq!(rec.dead_rank, 1);
    // Died at step 9; the last checkpoint was the step-6 boundary.
    assert_eq!(rec.replayed_steps, 3);
    // Detection is heartbeat-bound: the lease TTL plus polling slack
    // (generous for loaded CI machines, still far under a recv stall).
    let bound = cfg.heartbeat.timeout.as_secs_f64() * 2.0 + 0.5;
    assert!(
        rec.detection_s <= bound,
        "detection took {:.3}s (bound {bound:.3}s)",
        rec.detection_s
    );
    assert!(rec.total_s >= rec.detection_s);
    assert_eq!((report.initial_world, report.final_world), (3, 2));
    assert_eq!(report.final_epoch, 1, "one epoch bump per shrink");
    assert!(!report.rejoined);
    assert_eq!(report.steps_completed, cfg.total_steps);
    assert!(
        report.final_loss < report.losses[0] * 0.5,
        "survivors must still converge: {} -> {}",
        report.losses[0],
        report.final_loss
    );
    std::fs::remove_file(&cfg.ckpt_path).ok();
}

#[test]
fn rejoin_resumes_from_checkpoint() {
    // Shrink then grow: rank 2 dies, recovers as a 2-rank world, and
    // rejoins at the next segment boundary from the checkpoint — under
    // a second epoch bump so stale traffic stays fenced.
    set_short_timeout();
    let mut cfg = ElasticConfig::quick("1G+2M");
    cfg.fault = Some(FaultSpec {
        rank: 2,
        at_step: 8,
        rejoin_after_segments: 1,
    });
    let report = train_elastic(&cfg).unwrap();
    assert!(report.rejoined, "rank 2 must rejoin after one segment");
    assert_eq!((report.initial_world, report.final_world), (3, 3));
    assert_eq!(report.final_epoch, 2, "one bump for shrink, one for grow");
    let rec = report.recovery.as_ref().expect("the death was recovered");
    assert_eq!(rec.dead_rank, 2);
    assert_eq!(report.steps_completed, cfg.total_steps);
    assert!(
        report.final_loss < report.losses[0] * 0.5,
        "shrink/regrow must not break convergence: {} -> {}",
        report.losses[0],
        report.final_loss
    );
    std::fs::remove_file(&cfg.ckpt_path).ok();
}

#[test]
fn batch_bigger_than_buckets_is_a_clean_error() {
    // The trainer guards this via cap_allocation: a global batch that
    // cannot fit devices*max_bucket must error with guidance, not hang.
    let err = kaitian::sched::cap_allocation(&[40, 40], 16).unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("cannot fit"), "{msg}");
    assert!(msg.contains("global batch"), "{msg}");
}
