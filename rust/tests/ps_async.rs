//! Acceptance suite for `--grad_sync=ps_async` (bounded-staleness
//! parameter-server sync).
//!
//! Pure-rust tests drive the full client protocol — [`DdpEngine::ps_push`]
//! / `ps_install` / `ps_finish` against leader-hosted shards with real
//! p2p serve sessions — over an inproc cluster, checking the wire
//! protocol, the staleness-window invariant and bitwise determinism.
//! Engine-gated tests (skip without artifacts, like the rest of the
//! train-level suites) run the real trainer: `K = 0` must bitwise-match
//! synchronous sharded SGD, `K = 4` must stay within loss tolerance over
//! 20 steps, and the report JSON must surface the ps gauges.

use std::sync::{Arc, Mutex};

use kaitian::ddp::{DdpEngine, GradSyncMode};
use kaitian::device::parse_cluster;
use kaitian::group::{build_cluster, ClusterHandles, GroupMode, RelayKind};
use kaitian::metrics::{Accumulator, StepMetrics};
use kaitian::ps::{PsHub, PsHyper, PsPullStats, ShardPlan};
use kaitian::runtime::Engine;
use kaitian::train::loop_::sgd_update_shard;
use kaitian::train::{train, Checkpoint, LrSchedule, TrainOptions};

const BUCKET_BYTES: usize = 4 << 10; // 1024 f32 per bucket

/// Deterministic per-(worker, version) gradient sum.
fn grad(worker: usize, version: u64, n: usize) -> Vec<f32> {
    (0..n)
        .map(|i| ((i + worker * 11) % 17) as f32 * 0.125 - version as f32 * 0.002)
        .collect()
}

fn hyper(k: usize, workers: usize) -> PsHyper {
    PsHyper {
        schedule: LrSchedule::new(0.1, 0.1, 20),
        momentum: 0.9,
        weight_decay: 5e-4,
        grad_scale: 1.0 / workers as f32,
        steps_per_epoch: 10,
        staleness: k,
    }
}

/// Serial reference: every version applied in order with worker sums
/// folded in rank order — the state the hub must reach regardless of
/// arrival interleaving or remote routing.
fn serial_reference(
    hyper: &PsHyper,
    workers: usize,
    versions: u64,
    init: &[f32],
) -> (Vec<f32>, Vec<f32>) {
    let n = init.len();
    let mut params = init.to_vec();
    let mut momentum = vec![0.0_f32; n];
    for v in 0..versions {
        let mut sum = grad(0, v, n);
        for w in 1..workers {
            for (a, b) in sum.iter_mut().zip(&grad(w, v, n)) {
                *a += b;
            }
        }
        sgd_update_shard(&mut params, &mut momentum, &sum, hyper.hyper_at(v));
    }
    (params, momentum)
}

/// Run the full ps_async client protocol over a real cluster: every
/// rank pushes `versions` deterministic gradients through
/// [`DdpEngine::ps_push`], installs pulls, and finishes; remote flows go
/// through per-(shard, worker) serve sessions exactly as the trainer
/// spawns them. Returns each rank's final `(params, momentum)` plus the
/// per-rank folded pull stats.
fn run_protocol(
    handles: &ClusterHandles,
    hub: &Arc<PsHub>,
    versions: u64,
    init: &[f32],
    straggle: Option<usize>,
) -> Vec<(Vec<f32>, Vec<f32>, PsPullStats)> {
    let world = handles.groups.len();
    let n = init.len();
    let out = Mutex::new(vec![None; world]);
    std::thread::scope(|s| {
        for (rank, pg) in handles.groups.iter().enumerate() {
            let hub = hub.clone();
            let out = &out;
            s.spawn(move || {
                let ddp = DdpEngine::new(pg.as_ref(), BUCKET_BYTES);
                let mut params = init.to_vec();
                let mut momentum = vec![0.0_f32; n];
                let mut agg = PsPullStats::default();
                for v in 0..versions {
                    if v > 0 {
                        let (_, stats) = ddp.ps_install(&hub, &mut params, v - 1).unwrap();
                        agg.fold(&stats);
                    }
                    if straggle == Some(rank) {
                        std::thread::sleep(std::time::Duration::from_micros(200));
                    }
                    let g = grad(rank, v, n);
                    ddp.ps_push(&hub, &g, v, v + 1 == versions).unwrap();
                }
                ddp.ps_finish(&hub, &mut params, &mut momentum, versions - 1)
                    .unwrap();
                out.lock().unwrap()[rank] = Some((params, momentum, agg));
            });
        }
        // Serve sessions: one per (hosted shard, remote worker), on the
        // host's process group — the trainer's exact spawn pattern.
        for shard in 0..hub.plan().num_shards() {
            let host = hub.plan().host(shard);
            for wkr in (0..world).filter(|&w| w != host) {
                let hub = hub.clone();
                let pg = &handles.groups[host];
                s.spawn(move || hub.serve_remote(pg.as_ref(), shard, wkr).unwrap());
            }
        }
    });
    out.into_inner()
        .unwrap()
        .into_iter()
        .map(|x| x.expect("every rank reports"))
        .collect()
}

#[test]
fn remote_protocol_matches_serial_reference_bitwise() {
    // Two single-device groups: every shard is remote for exactly one
    // worker, so both the direct hub path and the wire protocol run.
    let devices = parse_cluster("1G+1M").unwrap();
    let handles = build_cluster(&devices, RelayKind::Inproc, GroupMode::Kaitian).unwrap();
    let world = handles.groups.len();
    let n = 5_000;
    let init: Vec<f32> = (0..n).map(|i| (i % 29) as f32 * 0.03125).collect();
    let versions = 12_u64;

    let ranges = DdpEngine::new(handles.groups[0].as_ref(), BUCKET_BYTES).sync_ranges(n);
    assert!(ranges.len() > 1, "need multiple buckets to exercise sharding");
    let plan = ShardPlan::build(n, &ranges, &handles.topo.leaders(), 0).unwrap();
    assert!(plan.num_shards() > 1, "two leaders must host two shards");
    let h = hyper(1, world);
    let zeros = vec![0.0_f32; n];
    let hub = PsHub::new(plan, h, world, &init, &zeros);

    let results = run_protocol(&handles, &hub, versions, &init, None);
    let (want_p, want_m) = serial_reference(&h, world, versions, &init);
    for (rank, (p, m, _)) in results.iter().enumerate() {
        assert_eq!(
            p.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            want_p.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            "rank {rank}: final params must bitwise-match the serial reference"
        );
        assert_eq!(m, &want_m, "rank {rank}: momentum must match");
    }
}

#[test]
fn staleness_window_invariant_holds_over_real_cluster() {
    // A deliberate straggler forces real run-ahead; the piggybacked
    // version vectors and lags must respect the K-window at every rank.
    for k in [0_usize, 2] {
        let devices = parse_cluster("1G+1M").unwrap();
        let handles = build_cluster(&devices, RelayKind::Inproc, GroupMode::Kaitian).unwrap();
        let world = handles.groups.len();
        let n = 2_048;
        let init = vec![0.25_f32; n];
        let versions = 16_u64;

        let ranges = DdpEngine::new(handles.groups[0].as_ref(), BUCKET_BYTES).sync_ranges(n);
        let plan = ShardPlan::build(n, &ranges, &handles.topo.leaders(), 0).unwrap();
        let h = hyper(k, world);
        let zeros = vec![0.0_f32; n];
        let hub = PsHub::new(plan, h, world, &init, &zeros);

        let results = run_protocol(&handles, &hub, versions, &init, Some(0));
        for (rank, (_, _, stats)) in results.iter().enumerate() {
            assert!(
                stats.lag <= k as u64,
                "K={k} rank {rank}: observed lag {} breaks the window",
                stats.lag
            );
            assert_eq!(
                stats.versions.len(),
                world,
                "K={k} rank {rank}: version vector must cover every worker"
            );
            assert!(
                stats.applied >= versions as i64 - 2 - k as i64,
                "K={k} rank {rank}: last install saw version {}",
                stats.applied
            );
        }
        // Still deterministic: both ranks end on the reference state.
        let (want_p, _) = serial_reference(&h, world, versions, &init);
        for (rank, (p, _, _)) in results.iter().enumerate() {
            assert_eq!(p, &want_p, "K={k} rank {rank}: replica diverged");
        }
    }
}

#[test]
fn report_json_surfaces_ps_and_stale_gauges() {
    // The per-rank accumulator must carry the ps wait/ahead/lag gauges
    // and the mailbox stale-drop counter into the report JSON.
    let mut acc = Accumulator::default();
    let m = StepMetrics {
        ps_wait_s: 0.25,
        ps_ahead_s: 0.5,
        ps_lag: 3,
        stale_dropped: 7,
        ..Default::default()
    };
    acc.add(&m);
    let json = acc.to_json().to_string();
    for key in ["ps_wait_s", "ps_ahead_s", "ps_lag", "stale_dropped"] {
        assert!(json.contains(&format!("\"{key}\"")), "missing {key}: {json}");
    }
}

// --- engine-gated train-level parity (skip without artifacts) ---------

fn engine() -> Option<Arc<Engine>> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: no artifacts — run `make artifacts-quick`");
        return None;
    }
    Some(Arc::new(Engine::load(dir).expect("engine load")))
}

fn ckpt_path(name: &str) -> String {
    std::env::temp_dir()
        .join(format!("kaitian_ps_async_{}_{name}.ckpt", std::process::id()))
        .to_string_lossy()
        .into_owned()
}

fn parity_opts(sync: GradSyncMode, staleness: usize, ckpt: &str) -> TrainOptions {
    let mut opts = TrainOptions::quick_test("1G+1M");
    opts.epochs = 1;
    opts.steps_per_epoch = Some(6);
    opts.eval_batches = 0;
    opts.grad_sync = sync;
    opts.staleness = staleness;
    opts.ps_shards = 0;
    opts.checkpoint = Some(ckpt.into());
    opts
}

#[test]
fn train_k0_ps_async_bitwise_matches_sharded() {
    let Some(engine) = engine() else { return };
    let ps_path = ckpt_path("k0_ps");
    let sh_path = ckpt_path("k0_sharded");
    train(
        engine.clone(),
        &parity_opts(GradSyncMode::PsAsync, 0, &ps_path),
    )
    .unwrap();
    train(engine, &parity_opts(GradSyncMode::Sharded, 0, &sh_path)).unwrap();
    let ps = Checkpoint::load(&ps_path).unwrap();
    let sh = Checkpoint::load(&sh_path).unwrap();
    let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
    assert_eq!(
        bits(&ps.params),
        bits(&sh.params),
        "K=0 ps_async must be bitwise-identical to synchronous sharded SGD"
    );
    assert_eq!(bits(&ps.momentum), bits(&sh.momentum), "momentum too");
    let _ = std::fs::remove_file(&ps_path);
    let _ = std::fs::remove_file(&sh_path);
}

#[test]
fn train_k4_ps_async_stays_within_loss_tolerance() {
    let Some(engine) = engine() else { return };
    let mk = |sync, k, path: &str| {
        let mut opts = parity_opts(sync, k, path);
        opts.dataset_len = 512; // 32 steps/epoch available
        opts.steps_per_epoch = Some(20);
        opts
    };
    let k4_path = ckpt_path("k4_ps");
    let k0_path = ckpt_path("k0_ref");
    let sh_path = ckpt_path("k4_sharded");
    let k4 = train(
        engine.clone(),
        &mk(GradSyncMode::PsAsync, 4, &k4_path),
    )
    .unwrap();
    let k0 = train(
        engine.clone(),
        &mk(GradSyncMode::PsAsync, 0, &k0_path),
    )
    .unwrap();
    train(engine, &mk(GradSyncMode::Sharded, 0, &sh_path)).unwrap();

    // Loss parity over the 20-step run: identical extrapolation on both
    // sides, so any gap is genuine staleness drift.
    let (l4, l0) = (k4.final_loss().unwrap(), k0.final_loss().unwrap());
    assert!(
        (l4 - l0).abs() <= 1e-3,
        "K=4 epoch loss {l4:.6} drifts more than 1e-3 from K=0 {l0:.6}"
    );
    // Model-state parity against the synchronous baseline.
    let k4_ck = Checkpoint::load(&k4_path).unwrap();
    let sh_ck = Checkpoint::load(&sh_path).unwrap();
    let drift = k4_ck
        .params
        .iter()
        .zip(&sh_ck.params)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0_f32, f32::max);
    assert!(
        drift <= 1e-3,
        "K=4 params drift {drift} from synchronous sharded SGD"
    );
    for p in [&k4_path, &k0_path, &sh_path] {
        let _ = std::fs::remove_file(p);
    }
}

#[test]
fn train_report_json_carries_ps_fields_end_to_end() {
    let Some(engine) = engine() else { return };
    let mut opts = TrainOptions::quick_test("1G+1M");
    opts.epochs = 1;
    opts.steps_per_epoch = Some(4);
    opts.eval_batches = 0;
    opts.grad_sync = GradSyncMode::PsAsync;
    opts.staleness = 2;
    let report = train(engine, &opts).unwrap();
    assert_eq!(report.grad_sync, "ps_async");
    let json = report.to_json().to_string();
    for key in ["ps_wait_s", "ps_ahead_s", "ps_lag", "stale_dropped"] {
        assert!(json.contains(&format!("\"{key}\"")), "missing {key}");
    }
}
